//! Dense f32 vector math used on the L3 hot path.
//!
//! The GraB inner loop is two fused reductions (`dot`) plus a signed update
//! (`axpy`) per example; everything here is written allocation-free over
//! caller-provided slices. `dot`/`axpy` use 8-lane manual unrolling so LLVM
//! reliably vectorizes them (measured in benches/balance_hot.rs; see
//! EXPERIMENTS.md §Perf for the before/after of naive vs unrolled).

/// Zero-copy view over a contiguous row-major `[rows × d]` gradient block —
/// the executor's upload buffer seen as `rows` per-example gradients. This
/// is the unit of the ordering data path: policies receive whole blocks
/// through [`crate::ordering::OrderPolicy::observe_block`] instead of one
/// virtual call per example.
#[derive(Clone, Copy, Debug)]
pub struct GradBlock<'a> {
    data: &'a [f32],
    d: usize,
}

impl<'a> GradBlock<'a> {
    /// View `data` as `data.len() / d` rows of dimension `d`.
    pub fn new(data: &'a [f32], d: usize) -> GradBlock<'a> {
        assert!(d > 0, "GradBlock dimension must be positive");
        assert_eq!(
            data.len() % d,
            0,
            "GradBlock data ({}) not a multiple of d ({d})",
            data.len()
        );
        GradBlock { data, d }
    }

    /// Number of gradient rows in the block.
    pub fn rows(&self) -> usize {
        self.data.len() / self.d
    }

    /// Per-example gradient dimension.
    pub fn dim(&self) -> usize {
        self.d
    }

    /// The underlying contiguous `[rows × d]` buffer.
    pub fn data(&self) -> &'a [f32] {
        self.data
    }

    /// Row `i` as a `d`-slice.
    pub fn row(&self, i: usize) -> &'a [f32] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    /// Iterate rows in order.
    pub fn iter_rows(&self) -> std::slice::ChunksExact<'a, f32> {
        self.data.chunks_exact(self.d)
    }
}

/// Dot product with 8-way unrolled accumulators.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let chunks = a.len() / 8;
    let mut acc = [0.0f32; 8];
    for i in 0..chunks {
        let off = i * 8;
        for lane in 0..8 {
            acc[lane] += a[off + lane] * b[off + lane];
        }
    }
    let mut tail = 0.0f32;
    for i in chunks * 8..a.len() {
        tail += a[i] * b[i];
    }
    acc.iter().sum::<f32>() + tail
}

/// Naive scalar dot (kept for the perf ablation in benches/balance_hot.rs).
pub fn dot_naive(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// `y += alpha * x`, 8-way unrolled.
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    let chunks = x.len() / 8;
    for i in 0..chunks {
        let off = i * 8;
        for lane in 0..8 {
            y[off + lane] += alpha * x[off + lane];
        }
    }
    for i in chunks * 8..x.len() {
        y[i] += alpha * x[i];
    }
}

/// `out = a - b` (centered gradient), allocation-free.
pub fn sub_into(a: &[f32], b: &[f32], out: &mut [f32]) {
    assert_eq!(a.len(), b.len());
    assert_eq!(a.len(), out.len());
    for ((o, x), y) in out.iter_mut().zip(a).zip(b) {
        *o = x - y;
    }
}

/// Fused GraB decision statistic: returns `<s, g - m>` in one pass without
/// materializing the centered vector. Equivalent to
/// `dot(s, c)` with `c = g - m`, but with a single read of each operand.
pub fn dot_centered(s: &[f32], g: &[f32], m: &[f32]) -> f32 {
    assert_eq!(s.len(), g.len());
    assert_eq!(s.len(), m.len());
    // chunks_exact + fixed-size destructuring removes bounds checks and
    // lets LLVM keep 8 independent FMA accumulators (§Perf iteration 3).
    let mut acc = [0.0f32; 8];
    let (sc, st) = s.split_at(s.len() - s.len() % 8);
    let (gc, gt) = g.split_at(sc.len());
    let (mc, mt) = m.split_at(sc.len());
    for ((sv, gv), mv) in sc
        .chunks_exact(8)
        .zip(gc.chunks_exact(8))
        .zip(mc.chunks_exact(8))
    {
        for lane in 0..8 {
            acc[lane] += sv[lane] * (gv[lane] - mv[lane]);
        }
    }
    let mut tail = 0.0f32;
    for i in 0..st.len() {
        tail += st[i] * (gt[i] - mt[i]);
    }
    acc.iter().sum::<f32>() + tail
}

/// Fused signed update: `s += eps * (g - m)` in one pass.
pub fn axpy_centered(eps: f32, g: &[f32], m: &[f32], s: &mut [f32]) {
    assert_eq!(s.len(), g.len());
    assert_eq!(s.len(), m.len());
    let chunks = s.len() / 8;
    for i in 0..chunks {
        let off = i * 8;
        for lane in 0..8 {
            s[off + lane] += eps * (g[off + lane] - m[off + lane]);
        }
    }
    for i in chunks * 8..s.len() {
        s[i] += eps * (g[i] - m[i]);
    }
}

/// Fully fused GraB observe update: in ONE pass over the operands,
/// `s += eps * (g - m)` and `fresh += inv_n * g`. Saves a full re-read of
/// `g` vs doing the two updates separately (see EXPERIMENTS.md §Perf).
pub fn grab_update(
    eps: f32,
    inv_n: f32,
    g: &[f32],
    m: &[f32],
    s: &mut [f32],
    fresh: &mut [f32],
) {
    assert_eq!(g.len(), m.len());
    assert_eq!(g.len(), s.len());
    assert_eq!(g.len(), fresh.len());
    let split = g.len() - g.len() % 8;
    let (gc, gt) = g.split_at(split);
    let (mc, mt) = m.split_at(split);
    let (sc, st) = s.split_at_mut(split);
    let (fc, ft) = fresh.split_at_mut(split);
    for (((gv, mv), sv), fv) in gc
        .chunks_exact(8)
        .zip(mc.chunks_exact(8))
        .zip(sc.chunks_exact_mut(8))
        .zip(fc.chunks_exact_mut(8))
    {
        for lane in 0..8 {
            let gl = gv[lane];
            sv[lane] += eps * (gl - mv[lane]);
            fv[lane] += inv_n * gl;
        }
    }
    for i in 0..gt.len() {
        let gl = gt[i];
        st[i] += eps * (gl - mt[i]);
        ft[i] += inv_n * gl;
    }
}

/// Batched GraB decision statistic: `out[i] = <s, block.row(i) - m>` for
/// every row of a `[B × d]` block against ONE refresh of the running sum
/// `s` and stale mean `m`. This is the block counterpart of
/// [`dot_centered`]: `s`/`m` stay cache-hot across the whole block instead
/// of being re-streamed per example, which is what amortizes the observe
/// path (see benches/ordering_overhead.rs).
pub fn dot_centered_block(
    s: &[f32],
    m: &[f32],
    block: &[f32],
    d: usize,
    out: &mut Vec<f32>,
) {
    assert_eq!(s.len(), d);
    assert_eq!(m.len(), d);
    assert_eq!(block.len() % d, 0);
    out.clear();
    for row in block.chunks_exact(d) {
        out.push(dot_centered(s, row, m));
    }
}

/// Fused block accumulators: `signed += eps * g` and `sum += g` in ONE
/// pass over `g` (eps is ±1, so the signed update is an add/sub). Used by
/// the batched observe path to defer the running-sum and fresh-mean folds
/// to once per block.
pub fn sign_sum_accum(
    eps: f32,
    g: &[f32],
    signed: &mut [f32],
    sum: &mut [f32],
) {
    assert_eq!(g.len(), signed.len());
    assert_eq!(g.len(), sum.len());
    let split = g.len() - g.len() % 8;
    let (gc, gt) = g.split_at(split);
    let (sc, st) = signed.split_at_mut(split);
    let (uc, ut) = sum.split_at_mut(split);
    for ((gv, sv), uv) in gc
        .chunks_exact(8)
        .zip(sc.chunks_exact_mut(8))
        .zip(uc.chunks_exact_mut(8))
    {
        for lane in 0..8 {
            let gl = gv[lane];
            sv[lane] += eps * gl;
            uv[lane] += gl;
        }
    }
    for i in 0..gt.len() {
        let gl = gt[i];
        st[i] += eps * gl;
        ut[i] += gl;
    }
}

/// Block fold of the running signed sum: `s += signed - net * m`, where
/// `signed = Σ eps_i * g_i` and `net = Σ eps_i` over the block. Together
/// with [`sign_sum_accum`] this equals per-row `s += eps_i * (g_i - m)`
/// (bit-identical for a 1-row block) at one read of `m` per block.
pub fn fold_signed_block(
    signed: &[f32],
    net: f32,
    m: &[f32],
    s: &mut [f32],
) {
    assert_eq!(signed.len(), m.len());
    assert_eq!(signed.len(), s.len());
    let split = s.len() - s.len() % 8;
    let (dc, dt) = signed.split_at(split);
    let (mc, mt) = m.split_at(split);
    let (sc, st) = s.split_at_mut(split);
    for ((dv, mv), sv) in dc
        .chunks_exact(8)
        .zip(mc.chunks_exact(8))
        .zip(sc.chunks_exact_mut(8))
    {
        for lane in 0..8 {
            sv[lane] += dv[lane] - net * mv[lane];
        }
    }
    for i in 0..dt.len() {
        st[i] += dt[i] - net * mt[i];
    }
}

/// Fused pair-difference decision statistic: `<s, a - b>` in one pass
/// without materializing the difference — the PairBalance (CD-GraB)
/// counterpart of [`dot_centered`].
pub fn dot_diff(s: &[f32], a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(s.len(), a.len());
    assert_eq!(s.len(), b.len());
    let mut acc = [0.0f32; 8];
    let split = s.len() - s.len() % 8;
    let (sc, st) = s.split_at(split);
    let (ac, at) = a.split_at(split);
    let (bc, bt) = b.split_at(split);
    for ((sv, av), bv) in sc
        .chunks_exact(8)
        .zip(ac.chunks_exact(8))
        .zip(bc.chunks_exact(8))
    {
        for lane in 0..8 {
            acc[lane] += sv[lane] * (av[lane] - bv[lane]);
        }
    }
    let mut tail = 0.0f32;
    for i in 0..st.len() {
        tail += st[i] * (at[i] - bt[i]);
    }
    acc.iter().sum::<f32>() + tail
}

/// Fused pair-difference update: `s += eps * (a - b)` in one pass.
pub fn axpy_diff(eps: f32, a: &[f32], b: &[f32], s: &mut [f32]) {
    assert_eq!(s.len(), a.len());
    assert_eq!(s.len(), b.len());
    let split = s.len() - s.len() % 8;
    let (ac, at) = a.split_at(split);
    let (bc, bt) = b.split_at(split);
    let (sc, st) = s.split_at_mut(split);
    for ((av, bv), sv) in ac
        .chunks_exact(8)
        .zip(bc.chunks_exact(8))
        .zip(sc.chunks_exact_mut(8))
    {
        for lane in 0..8 {
            sv[lane] += eps * (av[lane] - bv[lane]);
        }
    }
    for i in 0..at.len() {
        st[i] += eps * (at[i] - bt[i]);
    }
}

/// ℓ2 norm.
pub fn norm2(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// ℓ∞ norm.
pub fn norm_inf(a: &[f32]) -> f32 {
    a.iter().fold(0.0f32, |m, x| m.max(x.abs()))
}

/// Elementwise add into accumulator.
pub fn add_into(acc: &mut [f32], x: &[f32]) {
    assert_eq!(acc.len(), x.len());
    for (a, v) in acc.iter_mut().zip(x) {
        *a += v;
    }
}

/// Scale in place.
pub fn scale(a: &mut [f32], alpha: f32) {
    for v in a.iter_mut() {
        *v *= alpha;
    }
}

/// Fill with zeros.
pub fn zero(a: &mut [f32]) {
    a.iter_mut().for_each(|v| *v = 0.0);
}

/// Mean of a set of equal-length vectors into `out`.
pub fn mean_into(vs: &[Vec<f32>], out: &mut [f32]) {
    zero(out);
    if vs.is_empty() {
        return;
    }
    for v in vs {
        add_into(out, v);
    }
    scale(out, 1.0 / vs.len() as f32);
}

/// Running maxima of prefix-sum norms (ℓ∞ and ℓ2) over vectors visited in
/// `order` — the herding objective of Eq. (3). Single pass, one scratch sum.
pub fn prefix_bounds(
    vs: &[Vec<f32>],
    center: &[f32],
    order: &[usize],
) -> (f32, f32) {
    let d = center.len();
    let mut sum = vec![0.0f32; d];
    let mut max_inf = 0.0f32;
    let mut max_l2 = 0.0f32;
    for &i in order {
        for j in 0..d {
            sum[j] += vs[i][j] - center[j];
        }
        max_inf = max_inf.max(norm_inf(&sum));
        max_l2 = max_l2.max(norm2(&sum));
    }
    (max_inf, max_l2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rvec(rng: &mut Rng, d: usize) -> Vec<f32> {
        (0..d).map(|_| rng.gauss() as f32).collect()
    }

    #[test]
    fn dot_matches_naive() {
        let mut rng = Rng::new(1);
        for d in [0usize, 1, 7, 8, 9, 63, 64, 65, 1000] {
            let a = rvec(&mut rng, d);
            let b = rvec(&mut rng, d);
            let fast = dot(&a, &b);
            let naive = dot_naive(&a, &b);
            assert!(
                (fast - naive).abs() <= 1e-3 * (1.0 + naive.abs()),
                "d={d}: {fast} vs {naive}"
            );
        }
    }

    #[test]
    fn axpy_matches_reference() {
        let mut rng = Rng::new(2);
        for d in [1usize, 8, 13, 256] {
            let x = rvec(&mut rng, d);
            let mut y = rvec(&mut rng, d);
            let mut want = y.clone();
            axpy(0.5, &x, &mut y);
            for (w, xv) in want.iter_mut().zip(&x) {
                *w += 0.5 * xv;
            }
            for (a, b) in y.iter().zip(&want) {
                assert!((a - b).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn fused_centered_ops_match_two_step() {
        let mut rng = Rng::new(3);
        let d = 777;
        let s = rvec(&mut rng, d);
        let g = rvec(&mut rng, d);
        let m = rvec(&mut rng, d);
        let mut c = vec![0.0f32; d];
        sub_into(&g, &m, &mut c);
        let two_step = dot(&s, &c);
        let fused = dot_centered(&s, &g, &m);
        assert!((two_step - fused).abs() < 1e-3);

        let mut s1 = s.clone();
        let mut s2 = s.clone();
        axpy(-1.0, &c, &mut s1);
        axpy_centered(-1.0, &g, &m, &mut s2);
        for (a, b) in s1.iter().zip(&s2) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn grab_update_matches_two_step() {
        let mut rng = Rng::new(9);
        let d = 333;
        let g = rvec(&mut rng, d);
        let m = rvec(&mut rng, d);
        let mut s1 = rvec(&mut rng, d);
        let mut f1 = rvec(&mut rng, d);
        let mut s2 = s1.clone();
        let mut f2 = f1.clone();
        grab_update(-1.0, 0.25, &g, &m, &mut s1, &mut f1);
        axpy_centered(-1.0, &g, &m, &mut s2);
        axpy(0.25, &g, &mut f2);
        for (a, b) in s1.iter().zip(&s2) {
            assert!((a - b).abs() < 1e-6);
        }
        for (a, b) in f1.iter().zip(&f2) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn grad_block_views_rows() {
        let data: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let blk = GradBlock::new(&data, 3);
        assert_eq!(blk.rows(), 4);
        assert_eq!(blk.dim(), 3);
        assert_eq!(blk.row(1), &[3.0, 4.0, 5.0]);
        let rows: Vec<&[f32]> = blk.iter_rows().collect();
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[3], &[9.0, 10.0, 11.0]);
        // Empty block is legal (zero rows).
        assert_eq!(GradBlock::new(&[], 7).rows(), 0);
    }

    #[test]
    #[should_panic(expected = "not a multiple")]
    fn grad_block_rejects_ragged() {
        let _ = GradBlock::new(&[1.0, 2.0, 3.0], 2);
    }

    #[test]
    fn dot_centered_block_matches_per_row() {
        let mut rng = Rng::new(4);
        for (rows, d) in [(1usize, 17usize), (4, 8), (7, 33)] {
            let s = rvec(&mut rng, d);
            let m = rvec(&mut rng, d);
            let block: Vec<f32> = (0..rows * d)
                .map(|_| rng.gauss() as f32)
                .collect();
            let mut out = Vec::new();
            dot_centered_block(&s, &m, &block, d, &mut out);
            assert_eq!(out.len(), rows);
            for (i, got) in out.iter().enumerate() {
                let want =
                    dot_centered(&s, &block[i * d..(i + 1) * d], &m);
                assert!((got - want).abs() < 1e-4, "row {i}");
            }
        }
    }

    #[test]
    fn block_fold_matches_per_row_updates() {
        // sign_sum_accum + fold_signed_block over a block must equal the
        // per-row fused grab_update stream (same signs, same rows).
        let mut rng = Rng::new(5);
        let d = 67;
        let rows = 5;
        let m = rvec(&mut rng, d);
        let block: Vec<f32> =
            (0..rows * d).map(|_| rng.gauss() as f32).collect();
        let signs = [1.0f32, -1.0, -1.0, 1.0, -1.0];
        let inv_n = 0.125f32;

        let mut s_ref = rvec(&mut rng, d);
        let mut f_ref = rvec(&mut rng, d);
        let mut s_blk = s_ref.clone();
        let mut f_blk = f_ref.clone();

        for (i, &eps) in signs.iter().enumerate() {
            grab_update(
                eps,
                inv_n,
                &block[i * d..(i + 1) * d],
                &m,
                &mut s_ref,
                &mut f_ref,
            );
        }

        let mut signed = vec![0.0f32; d];
        let mut sum = vec![0.0f32; d];
        let mut net = 0.0f32;
        for (i, &eps) in signs.iter().enumerate() {
            sign_sum_accum(
                eps,
                &block[i * d..(i + 1) * d],
                &mut signed,
                &mut sum,
            );
            net += eps;
        }
        fold_signed_block(&signed, net, &m, &mut s_blk);
        axpy(inv_n, &sum, &mut f_blk);

        for (a, b) in s_blk.iter().zip(&s_ref) {
            assert!((a - b).abs() < 1e-4);
        }
        for (a, b) in f_blk.iter().zip(&f_ref) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn single_row_block_fold_is_bit_identical_to_grab_update() {
        // The 1-row block path must reproduce Algorithm 4 exactly, so the
        // per-example compatibility shim keeps the paper semantics.
        let mut rng = Rng::new(6);
        let d = 41;
        let g = rvec(&mut rng, d);
        let m = rvec(&mut rng, d);
        let mut s1 = rvec(&mut rng, d);
        let mut f1 = rvec(&mut rng, d);
        let mut s2 = s1.clone();
        let mut f2 = f1.clone();
        grab_update(-1.0, 0.25, &g, &m, &mut s1, &mut f1);

        let mut signed = vec![0.0f32; d];
        let mut sum = vec![0.0f32; d];
        sign_sum_accum(-1.0, &g, &mut signed, &mut sum);
        fold_signed_block(&signed, -1.0, &m, &mut s2);
        axpy(0.25, &sum, &mut f2);
        assert_eq!(s1, s2);
        assert_eq!(f1, f2);
    }

    #[test]
    fn diff_kernels_match_two_step() {
        let mut rng = Rng::new(7);
        let d = 99;
        let s = rvec(&mut rng, d);
        let a = rvec(&mut rng, d);
        let b = rvec(&mut rng, d);
        let mut diff = vec![0.0f32; d];
        sub_into(&a, &b, &mut diff);
        let want = dot(&s, &diff);
        let got = dot_diff(&s, &a, &b);
        assert!((want - got).abs() < 1e-3);

        let mut s1 = s.clone();
        let mut s2 = s.clone();
        axpy(-1.0, &diff, &mut s1);
        axpy_diff(-1.0, &a, &b, &mut s2);
        for (x, y) in s1.iter().zip(&s2) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn norms() {
        let v = [3.0f32, -4.0];
        assert!((norm2(&v) - 5.0).abs() < 1e-6);
        assert!((norm_inf(&v) - 4.0).abs() < 1e-6);
        assert_eq!(norm_inf(&[]), 0.0);
    }

    #[test]
    fn mean_into_works() {
        let vs = vec![vec![1.0f32, 2.0], vec![3.0, 6.0]];
        let mut out = vec![0.0f32; 2];
        mean_into(&vs, &mut out);
        assert_eq!(out, vec![2.0, 4.0]);
    }

    #[test]
    fn prefix_bounds_simple() {
        // Two opposite vectors, centered at zero: prefix max is the first.
        let vs = vec![vec![1.0f32, 0.0], vec![-1.0, 0.0]];
        let center = vec![0.0f32, 0.0];
        let (inf, l2) = prefix_bounds(&vs, &center, &[0, 1]);
        assert!((inf - 1.0).abs() < 1e-6);
        assert!((l2 - 1.0).abs() < 1e-6);
    }

    #[test]
    fn prefix_bounds_order_matters() {
        // [1,1,-1,-1] ordering vs interleaved [1,-1,1,-1].
        let vs: Vec<Vec<f32>> =
            vec![vec![1.0], vec![1.0], vec![-1.0], vec![-1.0]];
        let c = vec![0.0f32];
        let (bad, _) = prefix_bounds(&vs, &c, &[0, 1, 2, 3]);
        let (good, _) = prefix_bounds(&vs, &c, &[0, 2, 1, 3]);
        assert!(bad > good);
        assert!((bad - 2.0).abs() < 1e-6);
        assert!((good - 1.0).abs() < 1e-6);
    }
}
