//! Dense f32 vector math used on the L3 hot path.
//!
//! The GraB inner loop is two fused reductions (`dot`) plus a signed update
//! (`axpy`) per example; everything here is written allocation-free over
//! caller-provided slices. The free functions are the **scalar reference
//! tier**: 8-lane manually unrolled loops (bounds-check-free
//! `chunks_exact` + `split_at`) that LLVM vectorizes reliably. [`Kernel`]
//! layers two faster, runtime-dispatched tiers on top — AVX2 `std::arch`
//! kernels (the private `simd` module) and a row-parallel block path
//! ([`par`]) — both **bit-identical** to the scalar tier by construction
//! (determinism contract 7 in docs/determinism.md; see docs/perf.md for
//! the tier design and how to read the recorded `BENCH_*.json`
//! trajectory, measured in benches/balance_hot.rs).

pub mod par;
#[cfg(target_arch = "x86_64")]
mod simd;

use std::sync::atomic::{AtomicU8, Ordering};

/// Zero-copy view over a contiguous row-major `[rows × d]` gradient block —
/// the executor's upload buffer seen as `rows` per-example gradients. This
/// is the unit of the ordering data path: policies receive whole blocks
/// through [`crate::ordering::OrderPolicy::observe_block`] instead of one
/// virtual call per example.
#[derive(Clone, Copy, Debug)]
pub struct GradBlock<'a> {
    data: &'a [f32],
    d: usize,
}

impl<'a> GradBlock<'a> {
    /// View `data` as `data.len() / d` rows of dimension `d`.
    pub fn new(data: &'a [f32], d: usize) -> GradBlock<'a> {
        assert!(d > 0, "GradBlock dimension must be positive");
        assert_eq!(
            data.len() % d,
            0,
            "GradBlock data ({}) not a multiple of d ({d})",
            data.len()
        );
        GradBlock { data, d }
    }

    /// Number of gradient rows in the block.
    pub fn rows(&self) -> usize {
        self.data.len() / self.d
    }

    /// Per-example gradient dimension.
    pub fn dim(&self) -> usize {
        self.d
    }

    /// The underlying contiguous `[rows × d]` buffer.
    pub fn data(&self) -> &'a [f32] {
        self.data
    }

    /// Row `i` as a `d`-slice.
    pub fn row(&self, i: usize) -> &'a [f32] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    /// Iterate rows in order.
    pub fn iter_rows(&self) -> std::slice::ChunksExact<'a, f32> {
        self.data.chunks_exact(self.d)
    }
}

/// Dot product with 8-way unrolled accumulators.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let split = a.len() - a.len() % 8;
    let (ac, at) = a.split_at(split);
    let (bc, bt) = b.split_at(split);
    let mut acc = [0.0f32; 8];
    for (av, bv) in ac.chunks_exact(8).zip(bc.chunks_exact(8)) {
        for lane in 0..8 {
            acc[lane] += av[lane] * bv[lane];
        }
    }
    let mut tail = 0.0f32;
    for (x, y) in at.iter().zip(bt) {
        tail += x * y;
    }
    acc.iter().sum::<f32>() + tail
}

/// Naive scalar dot (kept for the perf ablation in benches/balance_hot.rs).
pub fn dot_naive(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// `y += alpha * x`, 8-way unrolled.
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    let split = x.len() - x.len() % 8;
    let (xc, xt) = x.split_at(split);
    let (yc, yt) = y.split_at_mut(split);
    for (xv, yv) in xc.chunks_exact(8).zip(yc.chunks_exact_mut(8)) {
        for lane in 0..8 {
            yv[lane] += alpha * xv[lane];
        }
    }
    for (yv, xv) in yt.iter_mut().zip(xt) {
        *yv += alpha * xv;
    }
}

/// `out = a - b` (centered gradient), allocation-free.
pub fn sub_into(a: &[f32], b: &[f32], out: &mut [f32]) {
    assert_eq!(a.len(), b.len());
    assert_eq!(a.len(), out.len());
    for ((o, x), y) in out.iter_mut().zip(a).zip(b) {
        *o = x - y;
    }
}

/// Fused GraB decision statistic: returns `<s, g - m>` in one pass without
/// materializing the centered vector. Equivalent to
/// `dot(s, c)` with `c = g - m`, but with a single read of each operand.
pub fn dot_centered(s: &[f32], g: &[f32], m: &[f32]) -> f32 {
    assert_eq!(s.len(), g.len());
    assert_eq!(s.len(), m.len());
    // chunks_exact + fixed-size destructuring removes bounds checks and
    // lets LLVM keep 8 independent accumulators (docs/perf.md, scalar
    // tier).
    let mut acc = [0.0f32; 8];
    let (sc, st) = s.split_at(s.len() - s.len() % 8);
    let (gc, gt) = g.split_at(sc.len());
    let (mc, mt) = m.split_at(sc.len());
    for ((sv, gv), mv) in sc
        .chunks_exact(8)
        .zip(gc.chunks_exact(8))
        .zip(mc.chunks_exact(8))
    {
        for lane in 0..8 {
            acc[lane] += sv[lane] * (gv[lane] - mv[lane]);
        }
    }
    let mut tail = 0.0f32;
    for i in 0..st.len() {
        tail += st[i] * (gt[i] - mt[i]);
    }
    acc.iter().sum::<f32>() + tail
}

/// Fused signed update: `s += eps * (g - m)` in one pass.
pub fn axpy_centered(eps: f32, g: &[f32], m: &[f32], s: &mut [f32]) {
    assert_eq!(s.len(), g.len());
    assert_eq!(s.len(), m.len());
    let split = s.len() - s.len() % 8;
    let (gc, gt) = g.split_at(split);
    let (mc, mt) = m.split_at(split);
    let (sc, st) = s.split_at_mut(split);
    for ((gv, mv), sv) in gc
        .chunks_exact(8)
        .zip(mc.chunks_exact(8))
        .zip(sc.chunks_exact_mut(8))
    {
        for lane in 0..8 {
            sv[lane] += eps * (gv[lane] - mv[lane]);
        }
    }
    for i in 0..gt.len() {
        st[i] += eps * (gt[i] - mt[i]);
    }
}

/// Fully fused GraB observe update: in ONE pass over the operands,
/// `s += eps * (g - m)` and `fresh += inv_n * g`. Saves a full re-read of
/// `g` vs doing the two updates separately (see docs/perf.md).
pub fn grab_update(
    eps: f32,
    inv_n: f32,
    g: &[f32],
    m: &[f32],
    s: &mut [f32],
    fresh: &mut [f32],
) {
    assert_eq!(g.len(), m.len());
    assert_eq!(g.len(), s.len());
    assert_eq!(g.len(), fresh.len());
    let split = g.len() - g.len() % 8;
    let (gc, gt) = g.split_at(split);
    let (mc, mt) = m.split_at(split);
    let (sc, st) = s.split_at_mut(split);
    let (fc, ft) = fresh.split_at_mut(split);
    for (((gv, mv), sv), fv) in gc
        .chunks_exact(8)
        .zip(mc.chunks_exact(8))
        .zip(sc.chunks_exact_mut(8))
        .zip(fc.chunks_exact_mut(8))
    {
        for lane in 0..8 {
            let gl = gv[lane];
            sv[lane] += eps * (gl - mv[lane]);
            fv[lane] += inv_n * gl;
        }
    }
    for i in 0..gt.len() {
        let gl = gt[i];
        st[i] += eps * (gl - mt[i]);
        ft[i] += inv_n * gl;
    }
}

/// Batched GraB decision statistic: `out[i] = <s, block.row(i) - m>` for
/// every row of a `[B × d]` block against ONE refresh of the running sum
/// `s` and stale mean `m`. This is the block counterpart of
/// [`dot_centered`]: `s`/`m` stay cache-hot across the whole block instead
/// of being re-streamed per example, which is what amortizes the observe
/// path (see benches/ordering_overhead.rs).
pub fn dot_centered_block(
    s: &[f32],
    m: &[f32],
    block: &[f32],
    d: usize,
    out: &mut Vec<f32>,
) {
    assert_eq!(s.len(), d);
    assert_eq!(m.len(), d);
    assert_eq!(block.len() % d, 0);
    out.clear();
    for row in block.chunks_exact(d) {
        out.push(dot_centered(s, row, m));
    }
}

/// Fused block accumulators: `signed += eps * g` and `sum += g` in ONE
/// pass over `g` (eps is ±1, so the signed update is an add/sub). Used by
/// the batched observe path to defer the running-sum and fresh-mean folds
/// to once per block.
pub fn sign_sum_accum(
    eps: f32,
    g: &[f32],
    signed: &mut [f32],
    sum: &mut [f32],
) {
    assert_eq!(g.len(), signed.len());
    assert_eq!(g.len(), sum.len());
    let split = g.len() - g.len() % 8;
    let (gc, gt) = g.split_at(split);
    let (sc, st) = signed.split_at_mut(split);
    let (uc, ut) = sum.split_at_mut(split);
    for ((gv, sv), uv) in gc
        .chunks_exact(8)
        .zip(sc.chunks_exact_mut(8))
        .zip(uc.chunks_exact_mut(8))
    {
        for lane in 0..8 {
            let gl = gv[lane];
            sv[lane] += eps * gl;
            uv[lane] += gl;
        }
    }
    for i in 0..gt.len() {
        let gl = gt[i];
        st[i] += eps * gl;
        ut[i] += gl;
    }
}

/// Whole-block form of [`sign_sum_accum`]: for every row `i` of the
/// `[B × d]` block, `signed += eps[i] * row_i` and `sum += row_i`. This
/// is the scalar reference of the pass [`Kernel::accum_signed_sum`]
/// dispatches (the SIMD tier vectorizes each row; the parallel tier
/// splits the columns across workers — per-element accumulation order is
/// row-major either way, so all tiers are bit-identical).
pub fn accum_signed_sum(
    eps: &[f32],
    block: &[f32],
    d: usize,
    signed: &mut [f32],
    sum: &mut [f32],
) {
    assert!(d > 0, "accum_signed_sum dimension must be positive");
    assert_eq!(block.len(), eps.len() * d);
    for (row, &e) in block.chunks_exact(d).zip(eps) {
        sign_sum_accum(e, row, signed, sum);
    }
}

/// Block fold of the running signed sum: `s += signed - net * m`, where
/// `signed = Σ eps_i * g_i` and `net = Σ eps_i` over the block. Together
/// with [`sign_sum_accum`] this equals per-row `s += eps_i * (g_i - m)`
/// (bit-identical for a 1-row block) at one read of `m` per block.
pub fn fold_signed_block(
    signed: &[f32],
    net: f32,
    m: &[f32],
    s: &mut [f32],
) {
    assert_eq!(signed.len(), m.len());
    assert_eq!(signed.len(), s.len());
    let split = s.len() - s.len() % 8;
    let (dc, dt) = signed.split_at(split);
    let (mc, mt) = m.split_at(split);
    let (sc, st) = s.split_at_mut(split);
    for ((dv, mv), sv) in dc
        .chunks_exact(8)
        .zip(mc.chunks_exact(8))
        .zip(sc.chunks_exact_mut(8))
    {
        for lane in 0..8 {
            sv[lane] += dv[lane] - net * mv[lane];
        }
    }
    for i in 0..dt.len() {
        st[i] += dt[i] - net * mt[i];
    }
}

/// Fused pair-difference decision statistic: `<s, a - b>` in one pass
/// without materializing the difference — the PairBalance (CD-GraB)
/// counterpart of [`dot_centered`].
pub fn dot_diff(s: &[f32], a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(s.len(), a.len());
    assert_eq!(s.len(), b.len());
    let mut acc = [0.0f32; 8];
    let split = s.len() - s.len() % 8;
    let (sc, st) = s.split_at(split);
    let (ac, at) = a.split_at(split);
    let (bc, bt) = b.split_at(split);
    for ((sv, av), bv) in sc
        .chunks_exact(8)
        .zip(ac.chunks_exact(8))
        .zip(bc.chunks_exact(8))
    {
        for lane in 0..8 {
            acc[lane] += sv[lane] * (av[lane] - bv[lane]);
        }
    }
    let mut tail = 0.0f32;
    for i in 0..st.len() {
        tail += st[i] * (at[i] - bt[i]);
    }
    acc.iter().sum::<f32>() + tail
}

/// Fused pair-difference update: `s += eps * (a - b)` in one pass.
pub fn axpy_diff(eps: f32, a: &[f32], b: &[f32], s: &mut [f32]) {
    assert_eq!(s.len(), a.len());
    assert_eq!(s.len(), b.len());
    let split = s.len() - s.len() % 8;
    let (ac, at) = a.split_at(split);
    let (bc, bt) = b.split_at(split);
    let (sc, st) = s.split_at_mut(split);
    for ((av, bv), sv) in ac
        .chunks_exact(8)
        .zip(bc.chunks_exact(8))
        .zip(sc.chunks_exact_mut(8))
    {
        for lane in 0..8 {
            sv[lane] += eps * (av[lane] - bv[lane]);
        }
    }
    for i in 0..at.len() {
        st[i] += eps * (at[i] - bt[i]);
    }
}

/// Runtime-selected implementation tier for the balance hot-path
/// kernels (docs/perf.md). All tiers are bit-identical by construction
/// — same 8-lane accumulator structure, separate mul then add (no FMA
/// contraction), same left-to-right lane fold, same scalar tail — so
/// tier choice never changes an epoch order (determinism contract 7).
///
/// Policies snapshot a tier at construction ([`default_kernel`] unless
/// given one explicitly), so dispatch is decided once, not per call.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// Portable 8-lane unrolled scalar Rust — the reference tier.
    Scalar,
    /// AVX2 `std::arch` kernels (falls back to scalar off-x86_64 or
    /// when the CPU lacks AVX2).
    Simd,
    /// [`Kernel::Simd`] plus the row-parallel worker pool ([`par`]) for
    /// the block kernels; sequential kernels behave as `Simd`.
    SimdPar,
}

/// Blocks smaller than this many f32 elements stay on the current
/// thread under [`Kernel::SimdPar`] — pool hand-off costs more than it
/// saves. Purely a performance threshold: the parallel and serial
/// paths produce bit-identical output, so the cutover is unobservable.
const PAR_MIN_ELEMS: usize = 32 * 1024;

/// Cached one-shot AVX2 probe (`is_x86_feature_detected!`).
#[cfg(target_arch = "x86_64")]
fn avx2() -> bool {
    static AVX2: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *AVX2.get_or_init(|| is_x86_feature_detected!("avx2"))
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2() -> bool {
    false
}

/// `Kernel::Simd`'s per-row kernels as plain-fn wrappers for the
/// parallel pool (selected only after [`avx2`] confirmed support).
#[cfg(target_arch = "x86_64")]
fn simd_row_dot_centered(s: &[f32], g: &[f32], m: &[f32]) -> f32 {
    // SAFETY: callers select this wrapper only when `avx2()` is true.
    unsafe { simd::dot_centered(s, g, m) }
}

#[cfg(target_arch = "x86_64")]
fn simd_lane_accum(eps: f32, g: &[f32], signed: &mut [f32], sum: &mut [f32]) {
    // SAFETY: callers select this wrapper only when `avx2()` is true.
    unsafe { simd::sign_sum_accum(eps, g, signed, sum) }
}

impl Kernel {
    /// The best tier for this host: `SimdPar` when AVX2 is present,
    /// else the scalar reference tier.
    pub fn auto() -> Kernel {
        if avx2() {
            Kernel::SimdPar
        } else {
            Kernel::Scalar
        }
    }

    /// Stable tier name (config value / bench JSON `kernel` field).
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Simd => "simd",
            Kernel::SimdPar => "simd+par",
        }
    }

    /// Whether the AVX2 bodies are usable for this tier on this host.
    #[cfg(target_arch = "x86_64")]
    fn simd_active(self) -> bool {
        self != Kernel::Scalar && avx2()
    }

    /// Whether a block of `elems` f32s goes to the worker pool.
    fn par_active(self, elems: usize) -> bool {
        self == Kernel::SimdPar && elems >= PAR_MIN_ELEMS
    }

    /// Dispatched [`dot`].
    pub fn dot(self, a: &[f32], b: &[f32]) -> f32 {
        #[cfg(target_arch = "x86_64")]
        if self.simd_active() {
            // SAFETY: AVX2 presence verified by `simd_active`.
            return unsafe { simd::dot(a, b) };
        }
        dot(a, b)
    }

    /// Dispatched [`axpy`].
    pub fn axpy(self, alpha: f32, x: &[f32], y: &mut [f32]) {
        #[cfg(target_arch = "x86_64")]
        if self.simd_active() {
            // SAFETY: AVX2 presence verified by `simd_active`.
            return unsafe { simd::axpy(alpha, x, y) };
        }
        axpy(alpha, x, y)
    }

    /// Dispatched [`dot_centered`].
    pub fn dot_centered(self, s: &[f32], g: &[f32], m: &[f32]) -> f32 {
        #[cfg(target_arch = "x86_64")]
        if self.simd_active() {
            // SAFETY: AVX2 presence verified by `simd_active`.
            return unsafe { simd::dot_centered(s, g, m) };
        }
        dot_centered(s, g, m)
    }

    /// Dispatched [`dot_diff`].
    pub fn dot_diff(self, s: &[f32], a: &[f32], b: &[f32]) -> f32 {
        #[cfg(target_arch = "x86_64")]
        if self.simd_active() {
            // SAFETY: AVX2 presence verified by `simd_active`.
            return unsafe { simd::dot_diff(s, a, b) };
        }
        dot_diff(s, a, b)
    }

    /// Dispatched [`axpy_diff`].
    pub fn axpy_diff(self, eps: f32, a: &[f32], b: &[f32], s: &mut [f32]) {
        #[cfg(target_arch = "x86_64")]
        if self.simd_active() {
            // SAFETY: AVX2 presence verified by `simd_active`.
            return unsafe { simd::axpy_diff(eps, a, b, s) };
        }
        axpy_diff(eps, a, b, s)
    }

    /// Dispatched [`fold_signed_block`].
    pub fn fold_signed_block(
        self,
        signed: &[f32],
        net: f32,
        m: &[f32],
        s: &mut [f32],
    ) {
        #[cfg(target_arch = "x86_64")]
        if self.simd_active() {
            // SAFETY: AVX2 presence verified by `simd_active`.
            return unsafe { simd::fold_signed_block(signed, net, m, s) };
        }
        fold_signed_block(signed, net, m, s)
    }

    /// Dispatched [`dot_centered_block`]. Under [`Kernel::SimdPar`] the
    /// independent rows are split across the worker pool with disjoint
    /// per-row output slots ([`par::dot_centered_block`]).
    pub fn dot_centered_block(
        self,
        s: &[f32],
        m: &[f32],
        block: &[f32],
        d: usize,
        out: &mut Vec<f32>,
    ) {
        if self.par_active(block.len()) {
            par::dot_centered_block(s, m, block, d, out, self.row_dot());
            return;
        }
        #[cfg(target_arch = "x86_64")]
        if self.simd_active() {
            assert_eq!(s.len(), d);
            assert_eq!(m.len(), d);
            assert_eq!(block.len() % d, 0);
            out.clear();
            for row in block.chunks_exact(d) {
                // SAFETY: AVX2 presence verified by `simd_active`.
                out.push(unsafe { simd::dot_centered(s, row, m) });
            }
            return;
        }
        dot_centered_block(s, m, block, d, out);
    }

    /// Dispatched [`accum_signed_sum`]. Under [`Kernel::SimdPar`] the
    /// columns are split across the worker pool; every worker walks all
    /// rows in order over its disjoint column range, so each element of
    /// `signed`/`sum` sees exactly the serial accumulation order
    /// ([`par::accum_signed_sum`]).
    pub fn accum_signed_sum(
        self,
        eps: &[f32],
        block: &[f32],
        d: usize,
        signed: &mut [f32],
        sum: &mut [f32],
    ) {
        if self.par_active(block.len()) {
            par::accum_signed_sum(
                eps,
                block,
                d,
                signed,
                sum,
                self.lane_accum(),
            );
            return;
        }
        #[cfg(target_arch = "x86_64")]
        if self.simd_active() {
            assert!(d > 0, "accum_signed_sum dimension must be positive");
            assert_eq!(block.len(), eps.len() * d);
            for (row, &e) in block.chunks_exact(d).zip(eps) {
                // SAFETY: AVX2 presence verified by `simd_active`.
                unsafe { simd::sign_sum_accum(e, row, signed, sum) };
            }
            return;
        }
        accum_signed_sum(eps, block, d, signed, sum);
    }

    /// Per-row `dot_centered` for the pool workers.
    fn row_dot(self) -> fn(&[f32], &[f32], &[f32]) -> f32 {
        #[cfg(target_arch = "x86_64")]
        if self.simd_active() {
            return simd_row_dot_centered;
        }
        dot_centered
    }

    /// Per-column-range `sign_sum_accum` for the pool workers.
    fn lane_accum(self) -> fn(f32, &[f32], &mut [f32], &mut [f32]) {
        #[cfg(target_arch = "x86_64")]
        if self.simd_active() {
            return simd_lane_accum;
        }
        sign_sum_accum
    }
}

/// Process-default kernel tier: 0 = unset (resolve [`Kernel::auto`]),
/// else `Kernel` discriminant + 1.
static DEFAULT_KERNEL: AtomicU8 = AtomicU8::new(0);

/// Pin the process-default kernel tier (the CLI's `--kernels`). Policies
/// constructed afterwards without an explicit tier snapshot this value.
/// Tests must use the `with_kernel` constructors instead — the default
/// is process-global and the test harness runs threads concurrently.
pub fn set_default_kernel(k: Kernel) {
    DEFAULT_KERNEL.store(k as u8 + 1, Ordering::Relaxed);
}

/// The process-default kernel tier ([`set_default_kernel`], else
/// [`Kernel::auto`] for this host).
pub fn default_kernel() -> Kernel {
    match DEFAULT_KERNEL.load(Ordering::Relaxed) {
        1 => Kernel::Scalar,
        2 => Kernel::Simd,
        3 => Kernel::SimdPar,
        _ => Kernel::auto(),
    }
}

/// ℓ2 norm.
pub fn norm2(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// ℓ∞ norm.
pub fn norm_inf(a: &[f32]) -> f32 {
    a.iter().fold(0.0f32, |m, x| m.max(x.abs()))
}

/// Elementwise add into accumulator.
pub fn add_into(acc: &mut [f32], x: &[f32]) {
    assert_eq!(acc.len(), x.len());
    for (a, v) in acc.iter_mut().zip(x) {
        *a += v;
    }
}

/// Scale in place.
pub fn scale(a: &mut [f32], alpha: f32) {
    for v in a.iter_mut() {
        *v *= alpha;
    }
}

/// Fill with zeros.
pub fn zero(a: &mut [f32]) {
    a.iter_mut().for_each(|v| *v = 0.0);
}

/// Mean of a set of equal-length vectors into `out`.
pub fn mean_into(vs: &[Vec<f32>], out: &mut [f32]) {
    zero(out);
    if vs.is_empty() {
        return;
    }
    for v in vs {
        add_into(out, v);
    }
    scale(out, 1.0 / vs.len() as f32);
}

/// Running maxima of prefix-sum norms (ℓ∞ and ℓ2) over vectors visited in
/// `order` — the herding objective of Eq. (3). Single pass, one scratch sum.
pub fn prefix_bounds(
    vs: &[Vec<f32>],
    center: &[f32],
    order: &[usize],
) -> (f32, f32) {
    let d = center.len();
    let mut sum = vec![0.0f32; d];
    let mut max_inf = 0.0f32;
    let mut max_l2 = 0.0f32;
    for &i in order {
        for j in 0..d {
            sum[j] += vs[i][j] - center[j];
        }
        max_inf = max_inf.max(norm_inf(&sum));
        max_l2 = max_l2.max(norm2(&sum));
    }
    (max_inf, max_l2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rvec(rng: &mut Rng, d: usize) -> Vec<f32> {
        (0..d).map(|_| rng.gauss() as f32).collect()
    }

    #[test]
    fn dot_matches_naive() {
        let mut rng = Rng::new(1);
        for d in [0usize, 1, 7, 8, 9, 63, 64, 65, 1000] {
            let a = rvec(&mut rng, d);
            let b = rvec(&mut rng, d);
            let fast = dot(&a, &b);
            let naive = dot_naive(&a, &b);
            assert!(
                (fast - naive).abs() <= 1e-3 * (1.0 + naive.abs()),
                "d={d}: {fast} vs {naive}"
            );
        }
    }

    #[test]
    fn axpy_matches_reference() {
        let mut rng = Rng::new(2);
        for d in [1usize, 8, 13, 256] {
            let x = rvec(&mut rng, d);
            let mut y = rvec(&mut rng, d);
            let mut want = y.clone();
            axpy(0.5, &x, &mut y);
            for (w, xv) in want.iter_mut().zip(&x) {
                *w += 0.5 * xv;
            }
            for (a, b) in y.iter().zip(&want) {
                assert!((a - b).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn fused_centered_ops_match_two_step() {
        let mut rng = Rng::new(3);
        let d = 777;
        let s = rvec(&mut rng, d);
        let g = rvec(&mut rng, d);
        let m = rvec(&mut rng, d);
        let mut c = vec![0.0f32; d];
        sub_into(&g, &m, &mut c);
        let two_step = dot(&s, &c);
        let fused = dot_centered(&s, &g, &m);
        assert!((two_step - fused).abs() < 1e-3);

        let mut s1 = s.clone();
        let mut s2 = s.clone();
        axpy(-1.0, &c, &mut s1);
        axpy_centered(-1.0, &g, &m, &mut s2);
        for (a, b) in s1.iter().zip(&s2) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn grab_update_matches_two_step() {
        let mut rng = Rng::new(9);
        let d = 333;
        let g = rvec(&mut rng, d);
        let m = rvec(&mut rng, d);
        let mut s1 = rvec(&mut rng, d);
        let mut f1 = rvec(&mut rng, d);
        let mut s2 = s1.clone();
        let mut f2 = f1.clone();
        grab_update(-1.0, 0.25, &g, &m, &mut s1, &mut f1);
        axpy_centered(-1.0, &g, &m, &mut s2);
        axpy(0.25, &g, &mut f2);
        for (a, b) in s1.iter().zip(&s2) {
            assert!((a - b).abs() < 1e-6);
        }
        for (a, b) in f1.iter().zip(&f2) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn grad_block_views_rows() {
        let data: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let blk = GradBlock::new(&data, 3);
        assert_eq!(blk.rows(), 4);
        assert_eq!(blk.dim(), 3);
        assert_eq!(blk.row(1), &[3.0, 4.0, 5.0]);
        let rows: Vec<&[f32]> = blk.iter_rows().collect();
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[3], &[9.0, 10.0, 11.0]);
        // Empty block is legal (zero rows).
        assert_eq!(GradBlock::new(&[], 7).rows(), 0);
    }

    #[test]
    #[should_panic(expected = "not a multiple")]
    fn grad_block_rejects_ragged() {
        let _ = GradBlock::new(&[1.0, 2.0, 3.0], 2);
    }

    #[test]
    fn dot_centered_block_matches_per_row() {
        let mut rng = Rng::new(4);
        for (rows, d) in [(1usize, 17usize), (4, 8), (7, 33)] {
            let s = rvec(&mut rng, d);
            let m = rvec(&mut rng, d);
            let block: Vec<f32> = (0..rows * d)
                .map(|_| rng.gauss() as f32)
                .collect();
            let mut out = Vec::new();
            dot_centered_block(&s, &m, &block, d, &mut out);
            assert_eq!(out.len(), rows);
            for (i, got) in out.iter().enumerate() {
                let want =
                    dot_centered(&s, &block[i * d..(i + 1) * d], &m);
                assert!((got - want).abs() < 1e-4, "row {i}");
            }
        }
    }

    #[test]
    fn block_fold_matches_per_row_updates() {
        // sign_sum_accum + fold_signed_block over a block must equal the
        // per-row fused grab_update stream (same signs, same rows).
        let mut rng = Rng::new(5);
        let d = 67;
        let rows = 5;
        let m = rvec(&mut rng, d);
        let block: Vec<f32> =
            (0..rows * d).map(|_| rng.gauss() as f32).collect();
        let signs = [1.0f32, -1.0, -1.0, 1.0, -1.0];
        let inv_n = 0.125f32;

        let mut s_ref = rvec(&mut rng, d);
        let mut f_ref = rvec(&mut rng, d);
        let mut s_blk = s_ref.clone();
        let mut f_blk = f_ref.clone();

        for (i, &eps) in signs.iter().enumerate() {
            grab_update(
                eps,
                inv_n,
                &block[i * d..(i + 1) * d],
                &m,
                &mut s_ref,
                &mut f_ref,
            );
        }

        let mut signed = vec![0.0f32; d];
        let mut sum = vec![0.0f32; d];
        let mut net = 0.0f32;
        for (i, &eps) in signs.iter().enumerate() {
            sign_sum_accum(
                eps,
                &block[i * d..(i + 1) * d],
                &mut signed,
                &mut sum,
            );
            net += eps;
        }
        fold_signed_block(&signed, net, &m, &mut s_blk);
        axpy(inv_n, &sum, &mut f_blk);

        for (a, b) in s_blk.iter().zip(&s_ref) {
            assert!((a - b).abs() < 1e-4);
        }
        for (a, b) in f_blk.iter().zip(&f_ref) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn single_row_block_fold_is_bit_identical_to_grab_update() {
        // The 1-row block path must reproduce Algorithm 4 exactly, so the
        // per-example compatibility shim keeps the paper semantics.
        let mut rng = Rng::new(6);
        let d = 41;
        let g = rvec(&mut rng, d);
        let m = rvec(&mut rng, d);
        let mut s1 = rvec(&mut rng, d);
        let mut f1 = rvec(&mut rng, d);
        let mut s2 = s1.clone();
        let mut f2 = f1.clone();
        grab_update(-1.0, 0.25, &g, &m, &mut s1, &mut f1);

        let mut signed = vec![0.0f32; d];
        let mut sum = vec![0.0f32; d];
        sign_sum_accum(-1.0, &g, &mut signed, &mut sum);
        fold_signed_block(&signed, -1.0, &m, &mut s2);
        axpy(0.25, &sum, &mut f2);
        assert_eq!(s1, s2);
        assert_eq!(f1, f2);
    }

    #[test]
    fn accum_signed_sum_matches_per_row_loop() {
        let mut rng = Rng::new(8);
        for (rows, d) in [(1usize, 9usize), (5, 67), (4, 8)] {
            let block: Vec<f32> =
                (0..rows * d).map(|_| rng.gauss() as f32).collect();
            let eps: Vec<f32> = (0..rows)
                .map(|i| if i % 2 == 0 { 1.0 } else { -1.0 })
                .collect();
            let mut signed = vec![0.0f32; d];
            let mut sum = vec![0.0f32; d];
            accum_signed_sum(&eps, &block, d, &mut signed, &mut sum);
            let mut signed_ref = vec![0.0f32; d];
            let mut sum_ref = vec![0.0f32; d];
            for (i, &e) in eps.iter().enumerate() {
                sign_sum_accum(
                    e,
                    &block[i * d..(i + 1) * d],
                    &mut signed_ref,
                    &mut sum_ref,
                );
            }
            assert_eq!(signed, signed_ref);
            assert_eq!(sum, sum_ref);
        }
    }

    #[test]
    fn kernel_tiers_are_bit_identical_smoke() {
        // In-module smoke check; the contract-7 suite in tests/kernels.rs
        // covers hostile floats, every ragged tail, and the policies.
        let mut rng = Rng::new(10);
        let d = 1027; // ragged tail, large enough to clear PAR_MIN_ELEMS
        let s = rvec(&mut rng, d);
        let m = rvec(&mut rng, d);
        let rows = 40;
        let block: Vec<f32> =
            (0..rows * d).map(|_| rng.gauss() as f32).collect();
        let eps: Vec<f32> = (0..rows)
            .map(|i| if i % 3 == 0 { 1.0 } else { -1.0 })
            .collect();
        let mut want_dots = Vec::new();
        let mut want_signed = vec![0.0f32; d];
        let mut want_sum = vec![0.0f32; d];
        Kernel::Scalar
            .dot_centered_block(&s, &m, &block, d, &mut want_dots);
        Kernel::Scalar.accum_signed_sum(
            &eps,
            &block,
            d,
            &mut want_signed,
            &mut want_sum,
        );
        for k in [Kernel::Simd, Kernel::SimdPar] {
            let mut dots = Vec::new();
            let mut signed = vec![0.0f32; d];
            let mut sum = vec![0.0f32; d];
            k.dot_centered_block(&s, &m, &block, d, &mut dots);
            k.accum_signed_sum(&eps, &block, d, &mut signed, &mut sum);
            assert_eq!(dots, want_dots, "{} dots", k.name());
            assert_eq!(signed, want_signed, "{} signed", k.name());
            assert_eq!(sum, want_sum, "{} sum", k.name());
        }
    }

    #[test]
    fn diff_kernels_match_two_step() {
        let mut rng = Rng::new(7);
        let d = 99;
        let s = rvec(&mut rng, d);
        let a = rvec(&mut rng, d);
        let b = rvec(&mut rng, d);
        let mut diff = vec![0.0f32; d];
        sub_into(&a, &b, &mut diff);
        let want = dot(&s, &diff);
        let got = dot_diff(&s, &a, &b);
        assert!((want - got).abs() < 1e-3);

        let mut s1 = s.clone();
        let mut s2 = s.clone();
        axpy(-1.0, &diff, &mut s1);
        axpy_diff(-1.0, &a, &b, &mut s2);
        for (x, y) in s1.iter().zip(&s2) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn norms() {
        let v = [3.0f32, -4.0];
        assert!((norm2(&v) - 5.0).abs() < 1e-6);
        assert!((norm_inf(&v) - 4.0).abs() < 1e-6);
        assert_eq!(norm_inf(&[]), 0.0);
    }

    #[test]
    fn mean_into_works() {
        let vs = vec![vec![1.0f32, 2.0], vec![3.0, 6.0]];
        let mut out = vec![0.0f32; 2];
        mean_into(&vs, &mut out);
        assert_eq!(out, vec![2.0, 4.0]);
    }

    #[test]
    fn prefix_bounds_simple() {
        // Two opposite vectors, centered at zero: prefix max is the first.
        let vs = vec![vec![1.0f32, 0.0], vec![-1.0, 0.0]];
        let center = vec![0.0f32, 0.0];
        let (inf, l2) = prefix_bounds(&vs, &center, &[0, 1]);
        assert!((inf - 1.0).abs() < 1e-6);
        assert!((l2 - 1.0).abs() < 1e-6);
    }

    #[test]
    fn prefix_bounds_order_matters() {
        // [1,1,-1,-1] ordering vs interleaved [1,-1,1,-1].
        let vs: Vec<Vec<f32>> =
            vec![vec![1.0], vec![1.0], vec![-1.0], vec![-1.0]];
        let c = vec![0.0f32];
        let (bad, _) = prefix_bounds(&vs, &c, &[0, 1, 2, 3]);
        let (good, _) = prefix_bounds(&vs, &c, &[0, 2, 1, 3]);
        assert!(bad > good);
        assert!((bad - 2.0).abs() < 1e-6);
        assert!((good - 1.0).abs() < 1e-6);
    }
}
