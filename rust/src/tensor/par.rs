//! Row-parallel block processing for the balance hot path: a persistent
//! `std::thread` worker pool that splits the independent work of a
//! `[B × d]` block across cores **without changing a single bit** of the
//! result (determinism contract 7, docs/perf.md).
//!
//! Two split strategies, chosen per kernel by what keeps the arithmetic
//! order serial:
//!
//! * [`dot_centered_block`] — **row split**. Each of the B decision dots
//!   reads the same block-entry `s`/`m` and writes its own output slot,
//!   so rows are fully independent; workers get contiguous row chunks
//!   with disjoint `split_at_mut` output slots. No reduction across
//!   workers exists, so there is no order to pin.
//! * [`accum_signed_sum`] — **column split**. The accumulators are
//!   shared across rows, so splitting rows would need a cross-worker
//!   reduction. Splitting *columns* instead gives each worker a disjoint
//!   range of `signed`/`sum`, and it walks ALL rows in order over that
//!   range — every element sees exactly the serial per-element
//!   accumulation order, so the result is bit-identical for any worker
//!   count.
//!
//! The pool is process-global and lazy: daemon threads are spawned on
//! first use and live for the process (the balance path runs every
//! block of every epoch — tearing the pool down between blocks would
//! dominate the win). Tasks borrow the caller's slices; [`Pool::run`]
//! erases the borrow lifetime to hand tasks to the long-lived workers,
//! which is sound because it blocks on a completion latch until every
//! task has finished. Worker panics are caught and re-raised on the
//! caller thread.

use std::collections::VecDeque;
use std::panic::{self, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread;

/// A borrowed unit of work handed to the pool.
type Task<'a> = Box<dyn FnOnce() + Send + 'a>;

/// Completion latch: counts outstanding tasks, records panics.
struct Latch {
    state: Mutex<(usize, bool)>,
    cv: Condvar,
}

impl Latch {
    fn new(count: usize) -> Latch {
        Latch { state: Mutex::new((count, false)), cv: Condvar::new() }
    }

    fn complete(&self, panicked: bool) {
        let mut st = self.state.lock().unwrap();
        st.0 -= 1;
        st.1 |= panicked;
        if st.0 == 0 {
            self.cv.notify_all();
        }
    }

    /// Block until every task completed; returns whether any panicked.
    fn wait(&self) -> bool {
        let mut st = self.state.lock().unwrap();
        while st.0 > 0 {
            st = self.cv.wait(st).unwrap();
        }
        st.1
    }
}

struct Job {
    task: Task<'static>,
    latch: Arc<Latch>,
}

struct Queue {
    jobs: Mutex<VecDeque<Job>>,
    cv: Condvar,
}

struct Pool {
    queue: Arc<Queue>,
    size: usize,
}

fn worker_loop(queue: &Queue) {
    loop {
        let job = {
            let mut jobs = queue.jobs.lock().unwrap();
            loop {
                if let Some(j) = jobs.pop_front() {
                    break j;
                }
                jobs = queue.cv.wait(jobs).unwrap();
            }
        };
        let task = job.task;
        let panicked =
            panic::catch_unwind(AssertUnwindSafe(move || task())).is_err();
        job.latch.complete(panicked);
    }
}

impl Pool {
    fn start() -> Pool {
        // At least 2 workers even on single-core hosts, so the parallel
        // path (and its determinism contract) is genuinely exercised
        // everywhere; the split stays deterministic either way.
        let size = thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .max(2);
        let queue = Arc::new(Queue {
            jobs: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
        });
        for i in 0..size {
            let q = Arc::clone(&queue);
            thread::Builder::new()
                .name(format!("grab-balance-{i}"))
                .spawn(move || worker_loop(&q))
                .expect("spawn balance worker");
        }
        Pool { queue, size }
    }

    /// Run borrowed tasks on the pool and block until all complete.
    fn run(&self, tasks: Vec<Task<'_>>) {
        if tasks.len() <= 1 {
            for t in tasks {
                t();
            }
            return;
        }
        let latch = Arc::new(Latch::new(tasks.len()));
        {
            let mut jobs = self.queue.jobs.lock().unwrap();
            for task in tasks {
                // SAFETY: `run` blocks on the latch until every task has
                // executed, so the borrows captured by `task` strictly
                // outlive its execution even though the type is erased
                // to 'static for the long-lived workers.
                let task: Task<'static> =
                    unsafe { std::mem::transmute(task) };
                jobs.push_back(Job { task, latch: Arc::clone(&latch) });
            }
        }
        self.queue.cv.notify_all();
        if latch.wait() {
            panic!("balance worker task panicked");
        }
    }
}

fn global() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(Pool::start)
}

/// Number of worker threads in the process-wide balance pool
/// (`max(2, available_parallelism)`), spawning it if needed.
pub fn pool_size() -> usize {
    global().size
}

/// Row-parallel [`super::dot_centered_block`]: `out[i] = <s, row_i - m>`
/// with the block's rows split into one contiguous chunk per worker and
/// disjoint `split_at_mut` output slots. `row_dot` is the per-row kernel
/// (scalar or AVX2 `dot_centered`); rows are data-independent, so the
/// result is bit-identical to the serial loop for any worker count.
pub fn dot_centered_block(
    s: &[f32],
    m: &[f32],
    block: &[f32],
    d: usize,
    out: &mut Vec<f32>,
    row_dot: fn(&[f32], &[f32], &[f32]) -> f32,
) {
    assert!(d > 0, "dot_centered_block dimension must be positive");
    assert_eq!(s.len(), d);
    assert_eq!(m.len(), d);
    assert_eq!(block.len() % d, 0);
    let rows = block.len() / d;
    out.clear();
    out.resize(rows, 0.0);
    let chunk = rows.div_ceil(pool_size()).max(1);
    let mut tasks: Vec<Task<'_>> = Vec::new();
    let mut rest: &mut [f32] = out.as_mut_slice();
    let mut start = 0;
    while start < rows {
        let end = (start + chunk).min(rows);
        let (slot, tail) =
            std::mem::take(&mut rest).split_at_mut(end - start);
        rest = tail;
        let rows_data = &block[start * d..end * d];
        tasks.push(Box::new(move || {
            for (o, row) in slot.iter_mut().zip(rows_data.chunks_exact(d)) {
                *o = row_dot(s, row, m);
            }
        }));
        start = end;
    }
    global().run(tasks);
}

/// Column-parallel [`super::accum_signed_sum`]: each worker owns a
/// disjoint column range of `signed`/`sum` and walks ALL rows in order
/// over it, so every element sees the exact serial accumulation order
/// (bit-identical for any worker count). Ranges are multiples of 8 so
/// each worker's slices keep the kernels' 8-lane main/tail split.
/// `lane_accum` is the per-(row, column-range) kernel (scalar or AVX2
/// `sign_sum_accum`).
pub fn accum_signed_sum(
    eps: &[f32],
    block: &[f32],
    d: usize,
    signed: &mut [f32],
    sum: &mut [f32],
    lane_accum: fn(f32, &[f32], &mut [f32], &mut [f32]),
) {
    assert!(d > 0, "accum_signed_sum dimension must be positive");
    assert_eq!(block.len(), eps.len() * d);
    assert_eq!(signed.len(), d);
    assert_eq!(sum.len(), d);
    let cols = d.div_ceil(pool_size()).next_multiple_of(8);
    let mut tasks: Vec<Task<'_>> = Vec::new();
    let mut signed_rest: &mut [f32] = signed;
    let mut sum_rest: &mut [f32] = sum;
    let mut c0 = 0;
    while c0 < d {
        let c1 = (c0 + cols).min(d);
        let (signed_slot, signed_tail) =
            std::mem::take(&mut signed_rest).split_at_mut(c1 - c0);
        signed_rest = signed_tail;
        let (sum_slot, sum_tail) =
            std::mem::take(&mut sum_rest).split_at_mut(c1 - c0);
        sum_rest = sum_tail;
        tasks.push(Box::new(move || {
            for (row, &e) in block.chunks_exact(d).zip(eps) {
                lane_accum(e, &row[c0..c1], signed_slot, sum_slot);
            }
        }));
        c0 = c1;
    }
    global().run(tasks);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor;
    use crate::util::rng::Rng;

    #[test]
    fn pool_has_at_least_two_workers() {
        assert!(pool_size() >= 2);
    }

    #[test]
    fn parallel_dot_centered_block_is_bit_identical_to_serial() {
        let mut rng = Rng::new(21);
        // Row counts around the chunk boundaries, ragged dims.
        for (rows, d) in
            [(1usize, 9usize), (2, 33), (5, 64), (17, 7), (64, 129)]
        {
            let s: Vec<f32> =
                (0..d).map(|_| rng.gauss() as f32).collect();
            let m: Vec<f32> =
                (0..d).map(|_| rng.gauss() as f32).collect();
            let block: Vec<f32> =
                (0..rows * d).map(|_| rng.gauss() as f32).collect();
            let mut serial = Vec::new();
            tensor::dot_centered_block(&s, &m, &block, d, &mut serial);
            let mut par_out = Vec::new();
            dot_centered_block(
                &s,
                &m,
                &block,
                d,
                &mut par_out,
                tensor::dot_centered,
            );
            assert_eq!(serial, par_out, "rows={rows} d={d}");
        }
    }

    #[test]
    fn parallel_accum_signed_sum_is_bit_identical_to_serial() {
        let mut rng = Rng::new(22);
        for (rows, d) in [(1usize, 8usize), (3, 17), (9, 65), (32, 256)] {
            let block: Vec<f32> =
                (0..rows * d).map(|_| rng.gauss() as f32).collect();
            let eps: Vec<f32> = (0..rows)
                .map(|i| if i % 2 == 0 { 1.0 } else { -1.0 })
                .collect();
            let mut signed_ser = vec![0.1f32; d];
            let mut sum_ser = vec![-0.2f32; d];
            tensor::accum_signed_sum(
                &eps,
                &block,
                d,
                &mut signed_ser,
                &mut sum_ser,
            );
            let mut signed_par = vec![0.1f32; d];
            let mut sum_par = vec![-0.2f32; d];
            accum_signed_sum(
                &eps,
                &block,
                d,
                &mut signed_par,
                &mut sum_par,
                tensor::sign_sum_accum,
            );
            assert_eq!(signed_ser, signed_par, "rows={rows} d={d}");
            assert_eq!(sum_ser, sum_par, "rows={rows} d={d}");
        }
    }

    #[test]
    fn worker_panic_propagates_to_caller() {
        let boom: Vec<Task<'_>> = vec![
            Box::new(|| {}),
            Box::new(|| panic!("intentional")),
            Box::new(|| {}),
        ];
        let hit = std::panic::catch_unwind(AssertUnwindSafe(|| {
            global().run(boom);
        }));
        assert!(hit.is_err(), "panic must cross the pool boundary");
    }
}
