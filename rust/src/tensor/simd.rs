//! AVX2 bodies of the balance hot-path kernels (`std::arch`), selected
//! at runtime by [`super::Kernel`] after a cached
//! `is_x86_feature_detected!("avx2")` probe.
//!
//! **Bit-identity discipline** (determinism contract 7, docs/perf.md):
//! every function here mirrors its scalar twin in `tensor/mod.rs`
//! operation for operation —
//!
//! * one `__m256` accumulator standing in for the scalar `[f32; 8]`
//!   lane array, over the same `split_at(len - len % 8)` main body;
//! * separate `_mm256_mul_ps` then `_mm256_add_ps`, never an FMA — x86
//!   packed mul/add round exactly like the scalar ops (including NaN
//!   propagation), while a fused multiply-add would skip the
//!   intermediate rounding and change low bits;
//! * reductions store the 8 lanes back to an array and fold them
//!   serially left-to-right, replicating `acc.iter().sum::<f32>()`;
//! * the `< 8` tail runs the identical scalar loop.
//!
//! So each SIMD kernel computes the *same floats in the same order* as
//! the scalar tier, merely 8 per instruction — equality is exact
//! (`to_bits`), not approximate, which is what lets kernel dispatch stay
//! outside the determinism contracts' replay state.

#![allow(unsafe_code)]

use std::arch::x86_64::{
    __m256, _mm256_add_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_set1_ps,
    _mm256_setzero_ps, _mm256_storeu_ps, _mm256_sub_ps,
};

/// Fold the 8 lanes serially in lane order — the exact order the scalar
/// tier's `acc.iter().sum::<f32>()` uses.
///
/// # Safety
/// Requires AVX2 (callers dispatch via `Kernel::simd_active`).
// SAFETY: the only unsafety is executing AVX2 instructions, which the
// caller contract guarantees are available; the store targets a local
// 8-float array via the unaligned `_mm256_storeu_ps`, exactly in bounds.
#[target_feature(enable = "avx2")]
unsafe fn hsum(v: __m256) -> f32 {
    let mut lanes = [0.0f32; 8];
    _mm256_storeu_ps(lanes.as_mut_ptr(), v);
    lanes.iter().sum()
}

/// AVX2 [`super::dot`].
///
/// # Safety
/// Requires AVX2 (callers dispatch via `Kernel::simd_active`).
// SAFETY: AVX2 is guaranteed by the caller contract; every
// `_mm256_loadu_ps` (unaligned, no alignment precondition) reads an
// 8-float `chunks_exact(8)` window, so all accesses are in bounds.
#[target_feature(enable = "avx2")]
pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let split = a.len() - a.len() % 8;
    let (ac, at) = a.split_at(split);
    let (bc, bt) = b.split_at(split);
    let mut acc = _mm256_setzero_ps();
    for (av, bv) in ac.chunks_exact(8).zip(bc.chunks_exact(8)) {
        let va = _mm256_loadu_ps(av.as_ptr());
        let vb = _mm256_loadu_ps(bv.as_ptr());
        acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb));
    }
    let mut tail = 0.0f32;
    for (x, y) in at.iter().zip(bt) {
        tail += x * y;
    }
    hsum(acc) + tail
}

/// AVX2 [`super::axpy`].
///
/// # Safety
/// Requires AVX2 (callers dispatch via `Kernel::simd_active`).
// SAFETY: AVX2 is guaranteed by the caller contract; unaligned
// loads/stores cover disjoint `chunks_exact(8)` / `chunks_exact_mut(8)`
// windows of the argument slices, so all accesses are in bounds.
#[target_feature(enable = "avx2")]
pub unsafe fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    let split = x.len() - x.len() % 8;
    let (xc, xt) = x.split_at(split);
    let (yc, yt) = y.split_at_mut(split);
    let va = _mm256_set1_ps(alpha);
    for (xv, yv) in xc.chunks_exact(8).zip(yc.chunks_exact_mut(8)) {
        let vx = _mm256_loadu_ps(xv.as_ptr());
        let vy = _mm256_loadu_ps(yv.as_ptr());
        let out = _mm256_add_ps(vy, _mm256_mul_ps(va, vx));
        _mm256_storeu_ps(yv.as_mut_ptr(), out);
    }
    for (yv, xv) in yt.iter_mut().zip(xt) {
        *yv += alpha * xv;
    }
}

/// AVX2 [`super::dot_centered`]: `<s, g - m>` in one pass.
///
/// # Safety
/// Requires AVX2 (callers dispatch via `Kernel::simd_active`).
// SAFETY: AVX2 is guaranteed by the caller contract; every unaligned
// load reads an 8-float `chunks_exact(8)` window of a length-checked
// slice, so all accesses are in bounds.
#[target_feature(enable = "avx2")]
pub unsafe fn dot_centered(s: &[f32], g: &[f32], m: &[f32]) -> f32 {
    assert_eq!(s.len(), g.len());
    assert_eq!(s.len(), m.len());
    let split = s.len() - s.len() % 8;
    let (sc, st) = s.split_at(split);
    let (gc, gt) = g.split_at(split);
    let (mc, mt) = m.split_at(split);
    let mut acc = _mm256_setzero_ps();
    for ((sv, gv), mv) in sc
        .chunks_exact(8)
        .zip(gc.chunks_exact(8))
        .zip(mc.chunks_exact(8))
    {
        let vs = _mm256_loadu_ps(sv.as_ptr());
        let vg = _mm256_loadu_ps(gv.as_ptr());
        let vm = _mm256_loadu_ps(mv.as_ptr());
        let c = _mm256_sub_ps(vg, vm);
        acc = _mm256_add_ps(acc, _mm256_mul_ps(vs, c));
    }
    let mut tail = 0.0f32;
    for i in 0..st.len() {
        tail += st[i] * (gt[i] - mt[i]);
    }
    hsum(acc) + tail
}

/// AVX2 [`super::dot_diff`]: `<s, a - b>` in one pass.
///
/// # Safety
/// Requires AVX2 (callers dispatch via `Kernel::simd_active`).
// SAFETY: AVX2 is guaranteed by the caller contract; every unaligned
// load reads an 8-float `chunks_exact(8)` window of a length-checked
// slice, so all accesses are in bounds.
#[target_feature(enable = "avx2")]
pub unsafe fn dot_diff(s: &[f32], a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(s.len(), a.len());
    assert_eq!(s.len(), b.len());
    let split = s.len() - s.len() % 8;
    let (sc, st) = s.split_at(split);
    let (ac, at) = a.split_at(split);
    let (bc, bt) = b.split_at(split);
    let mut acc = _mm256_setzero_ps();
    for ((sv, av), bv) in sc
        .chunks_exact(8)
        .zip(ac.chunks_exact(8))
        .zip(bc.chunks_exact(8))
    {
        let vs = _mm256_loadu_ps(sv.as_ptr());
        let va = _mm256_loadu_ps(av.as_ptr());
        let vb = _mm256_loadu_ps(bv.as_ptr());
        let diff = _mm256_sub_ps(va, vb);
        acc = _mm256_add_ps(acc, _mm256_mul_ps(vs, diff));
    }
    let mut tail = 0.0f32;
    for i in 0..st.len() {
        tail += st[i] * (at[i] - bt[i]);
    }
    hsum(acc) + tail
}

/// AVX2 [`super::axpy_diff`]: `s += eps * (a - b)` in one pass.
///
/// # Safety
/// Requires AVX2 (callers dispatch via `Kernel::simd_active`).
// SAFETY: AVX2 is guaranteed by the caller contract; unaligned
// loads/stores cover disjoint `chunks_exact(8)` / `chunks_exact_mut(8)`
// windows of length-checked slices, so all accesses are in bounds.
#[target_feature(enable = "avx2")]
pub unsafe fn axpy_diff(eps: f32, a: &[f32], b: &[f32], s: &mut [f32]) {
    assert_eq!(s.len(), a.len());
    assert_eq!(s.len(), b.len());
    let split = s.len() - s.len() % 8;
    let (ac, at) = a.split_at(split);
    let (bc, bt) = b.split_at(split);
    let (sc, st) = s.split_at_mut(split);
    let ve = _mm256_set1_ps(eps);
    for ((av, bv), sv) in ac
        .chunks_exact(8)
        .zip(bc.chunks_exact(8))
        .zip(sc.chunks_exact_mut(8))
    {
        let va = _mm256_loadu_ps(av.as_ptr());
        let vb = _mm256_loadu_ps(bv.as_ptr());
        let vs = _mm256_loadu_ps(sv.as_ptr());
        let diff = _mm256_sub_ps(va, vb);
        let out = _mm256_add_ps(vs, _mm256_mul_ps(ve, diff));
        _mm256_storeu_ps(sv.as_mut_ptr(), out);
    }
    for i in 0..at.len() {
        st[i] += eps * (at[i] - bt[i]);
    }
}

/// AVX2 [`super::sign_sum_accum`]: `signed += eps * g` and `sum += g`
/// in one pass over `g`.
///
/// # Safety
/// Requires AVX2 (callers dispatch via `Kernel::simd_active`).
// SAFETY: AVX2 is guaranteed by the caller contract; unaligned
// loads/stores cover disjoint `chunks_exact(8)` / `chunks_exact_mut(8)`
// windows of length-checked slices, so all accesses are in bounds.
#[target_feature(enable = "avx2")]
pub unsafe fn sign_sum_accum(
    eps: f32,
    g: &[f32],
    signed: &mut [f32],
    sum: &mut [f32],
) {
    assert_eq!(g.len(), signed.len());
    assert_eq!(g.len(), sum.len());
    let split = g.len() - g.len() % 8;
    let (gc, gt) = g.split_at(split);
    let (sc, st) = signed.split_at_mut(split);
    let (uc, ut) = sum.split_at_mut(split);
    let ve = _mm256_set1_ps(eps);
    for ((gv, sv), uv) in gc
        .chunks_exact(8)
        .zip(sc.chunks_exact_mut(8))
        .zip(uc.chunks_exact_mut(8))
    {
        let vg = _mm256_loadu_ps(gv.as_ptr());
        let vs = _mm256_loadu_ps(sv.as_ptr());
        let vu = _mm256_loadu_ps(uv.as_ptr());
        let s_out = _mm256_add_ps(vs, _mm256_mul_ps(ve, vg));
        let u_out = _mm256_add_ps(vu, vg);
        _mm256_storeu_ps(sv.as_mut_ptr(), s_out);
        _mm256_storeu_ps(uv.as_mut_ptr(), u_out);
    }
    for i in 0..gt.len() {
        let gl = gt[i];
        st[i] += eps * gl;
        ut[i] += gl;
    }
}

/// AVX2 [`super::fold_signed_block`]: `s += signed - net * m`.
///
/// # Safety
/// Requires AVX2 (callers dispatch via `Kernel::simd_active`).
// SAFETY: AVX2 is guaranteed by the caller contract; unaligned
// loads/stores cover disjoint `chunks_exact(8)` / `chunks_exact_mut(8)`
// windows of length-checked slices, so all accesses are in bounds.
#[target_feature(enable = "avx2")]
pub unsafe fn fold_signed_block(
    signed: &[f32],
    net: f32,
    m: &[f32],
    s: &mut [f32],
) {
    assert_eq!(signed.len(), m.len());
    assert_eq!(signed.len(), s.len());
    let split = s.len() - s.len() % 8;
    let (dc, dt) = signed.split_at(split);
    let (mc, mt) = m.split_at(split);
    let (sc, st) = s.split_at_mut(split);
    let vn = _mm256_set1_ps(net);
    for ((dv, mv), sv) in dc
        .chunks_exact(8)
        .zip(mc.chunks_exact(8))
        .zip(sc.chunks_exact_mut(8))
    {
        let vd = _mm256_loadu_ps(dv.as_ptr());
        let vm = _mm256_loadu_ps(mv.as_ptr());
        let vs = _mm256_loadu_ps(sv.as_ptr());
        // Scalar twin: `sv[lane] += dv[lane] - net * mv[lane]` — the
        // mul happens first, then the subtract, then the add.
        let prod = _mm256_mul_ps(vn, vm);
        let out = _mm256_add_ps(vs, _mm256_sub_ps(vd, prod));
        _mm256_storeu_ps(sv.as_mut_ptr(), out);
    }
    for i in 0..dt.len() {
        st[i] += dt[i] - net * mt[i];
    }
}

#[cfg(test)]
mod tests {
    use crate::tensor::{self, Kernel};
    use crate::util::rng::Rng;

    /// Hostile values every kernel must propagate exactly like scalar.
    fn hostile(rng: &mut Rng, d: usize) -> Vec<f32> {
        (0..d)
            .map(|i| match i % 7 {
                0 => f32::NAN,
                1 => f32::INFINITY,
                2 => f32::NEG_INFINITY,
                3 => 1.0e-40, // subnormal
                _ => rng.gauss() as f32,
            })
            .collect()
    }

    // Miri cannot execute vendor intrinsics (and reports no AVX2), so
    // the SIMD-vs-scalar equivalence tests only run natively.
    #[test]
    #[cfg_attr(miri, ignore)]
    fn avx2_reductions_match_scalar_bits_on_hostile_floats() {
        if !std::is_x86_feature_detected!("avx2") {
            eprintln!("skip: host lacks AVX2");
            return;
        }
        let mut rng = Rng::new(17);
        for d in [1usize, 7, 8, 9, 15, 16, 63, 64, 65, 1000] {
            let s = hostile(&mut rng, d);
            let a = hostile(&mut rng, d);
            let b = hostile(&mut rng, d);
            let pairs = [
                (tensor::dot(&s, &a), Kernel::Simd.dot(&s, &a)),
                (
                    tensor::dot_centered(&s, &a, &b),
                    Kernel::Simd.dot_centered(&s, &a, &b),
                ),
                (
                    tensor::dot_diff(&s, &a, &b),
                    Kernel::Simd.dot_diff(&s, &a, &b),
                ),
            ];
            for (i, (want, got)) in pairs.iter().enumerate() {
                assert_eq!(
                    want.to_bits(),
                    got.to_bits(),
                    "kernel {i} at d={d}: {want} vs {got}"
                );
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn avx2_updates_match_scalar_bits_on_hostile_floats() {
        if !std::is_x86_feature_detected!("avx2") {
            eprintln!("skip: host lacks AVX2");
            return;
        }
        let mut rng = Rng::new(19);
        for d in [1usize, 7, 9, 64, 65, 333] {
            let a = hostile(&mut rng, d);
            let b = hostile(&mut rng, d);
            let s0 = hostile(&mut rng, d);
            let bits = |v: &[f32]| {
                v.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            };

            let mut s_ref = s0.clone();
            let mut s_simd = s0.clone();
            tensor::axpy_diff(-1.0, &a, &b, &mut s_ref);
            Kernel::Simd.axpy_diff(-1.0, &a, &b, &mut s_simd);
            assert_eq!(bits(&s_ref), bits(&s_simd), "axpy_diff d={d}");

            let mut signed_ref = s0.clone();
            let mut sum_ref = b.clone();
            let mut signed_simd = s0.clone();
            let mut sum_simd = b.clone();
            tensor::sign_sum_accum(1.0, &a, &mut signed_ref, &mut sum_ref);
            Kernel::Simd.accum_signed_sum(
                &[1.0],
                &a,
                d,
                &mut signed_simd,
                &mut sum_simd,
            );
            assert_eq!(bits(&signed_ref), bits(&signed_simd));
            assert_eq!(bits(&sum_ref), bits(&sum_simd));

            let mut fold_ref = s0.clone();
            let mut fold_simd = s0.clone();
            tensor::fold_signed_block(&a, -3.0, &b, &mut fold_ref);
            Kernel::Simd.fold_signed_block(&a, -3.0, &b, &mut fold_simd);
            assert_eq!(bits(&fold_ref), bits(&fold_simd), "fold d={d}");

            let mut y_ref = s0.clone();
            let mut y_simd = s0.clone();
            tensor::axpy(0.5, &a, &mut y_ref);
            Kernel::Simd.axpy(0.5, &a, &mut y_simd);
            assert_eq!(bits(&y_ref), bits(&y_simd), "axpy d={d}");
        }
    }
}
