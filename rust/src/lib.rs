//! # grab — GraB: Finding Provably Better Data Permutations than Random Reshuffling
//!
//! A full-stack reproduction of Lu, Guo & De Sa (NeurIPS 2022). The crate is
//! the **Layer-3 coordinator** of a three-layer architecture:
//!
//! * **L3 (this crate)** — streaming data-pipeline orchestrator: dataset
//!   substrates, example-ordering policies (RR / SO / FlipFlop / Greedy
//!   Herding / GraB, plus CD-GraB's PairBalance and the sharded
//!   coordinator) streamed through the block-based [`ordering`] API,
//!   vector-balancing and herding algorithms, optimizer, training engine,
//!   threaded pipeline, and the experiment harness that regenerates every
//!   table and figure in the paper.
//! * **L2 (python/compile/model.py, build-time only)** — JAX models whose
//!   per-example gradient functions are AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels/, build-time only)** — Pallas kernels
//!   (tiled matmul, fused softmax-CE, the GraB balance step) called by L2 so
//!   they lower into the same HLO artifacts.
//!
//! At runtime the coordinator loads `artifacts/*.hlo.txt` through the PJRT C
//! API ([`runtime`]) and Python never executes on the request path.
//!
//! Quick start (after `make artifacts`):
//!
//! ```bash
//! cargo run --release --example quickstart
//! cargo run --release -- train --task mnist --ordering grab --epochs 5
//! cargo run --release -- exp fig1
//! ```
//!
//! See `rust/README.md` for the module map, the full command index, and
//! the shard wire-frame layout, and `docs/determinism.md` for the
//! equivalence contracts (per-example ≡ block, W=1 ≡ PairBalance, sync
//! ≡ async shards, sync ≡ pipeline, socket ≡ channel transport,
//! scalar ≡ SIMD ≡ row-parallel kernels) the test suite enforces; the
//! [`service`] daemon (`grab serve`) runs CD-GraB jobs over a registry
//! of dialed-in workers behind an HTTP control plane.
//! `docs/perf.md` covers the balance-kernel tiers and the recorded
//! `BENCH_*.json` perf trajectory, and `docs/audit.md` the [`audit`]
//! static pass (`grab audit`) that keeps the contracts' source-level
//! invariants from regressing.

#![warn(missing_docs)]

pub mod audit;
pub mod balance;
pub mod bench;
pub mod config;
pub mod data;
pub mod exp;
pub mod herding;
pub mod model;
pub mod optim;
pub mod ordering;
pub mod pipeline;
pub mod runtime;
pub mod service;
pub mod tensor;
pub mod train;
pub mod util;
pub mod xla;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
