//! `grab exp stream` — streaming ordering-quality experiment: a
//! [`StreamOrder`] sliding reservoir driven through frozen
//! [`DriftPlan`] schedules, with the two halves of determinism
//! contract 9 (docs/determinism.md) asserted by the run itself:
//!
//! 1. **Static half** — a prefilled reservoir with no membership
//!    events produces per-window orders bit-equal to a bare
//!    [`PairBalance`] over the same gradients (streaming is a strict
//!    generalization, not a different algorithm).
//! 2. **Transport half** — on a count-neutral schedule (steady churn
//!    over a full reservoir: every admit FIFO-evicts one unit, so the
//!    live count never changes), the sharded reservoir's merged orders
//!    are bit-equal across channel and loopback-TCP backends at every
//!    swept shard count. This is the same schedule the daemon's
//!    `stream` jobs run over leased links.
//!
//! Beyond the gates, a drift suite (explicit retirements, burst
//! admits, distribution shift) exercises the resize/re-link path and
//! records how the per-window herding bound and the carried-out
//! survivor accumulator behave under churn. Writes
//! `stream_windows.csv`: one row per (scenario, backend, window) with
//! the live count, herding bound, carry norm, lifetime reservoir
//! counters, and the ordering-overhead seconds.

use anyhow::Result;

use crate::ordering::stream::{DriftPlan, StreamOrder};
use crate::ordering::{OrderPolicy, PairBalance};
use crate::service::order_hash;
use crate::util::ser::{fmt_f, CsvWriter};

/// Parameters of the streaming reservoir experiment.
pub struct StreamExpConfig {
    /// Reservoir capacity, fully prefilled with units `0..n`.
    pub n: usize,
    /// Gradient dimension.
    pub d: usize,
    /// Windows per scenario.
    pub windows: usize,
    /// Observe block width.
    pub block: usize,
    /// Fresh units admitted per window on the churn schedules
    /// (`--admit-rate`).
    pub admit_rate: usize,
    /// Shard counts swept on the count-neutral transport gate.
    pub shard_counts: Vec<usize>,
    /// Seed for every drift plan (gradients and retirement sampling).
    pub seed: u64,
}

impl Default for StreamExpConfig {
    fn default() -> Self {
        StreamExpConfig {
            n: 2048,
            d: 128,
            windows: 8,
            block: 32,
            admit_rate: 32,
            shard_counts: vec![1, 4],
            seed: 0,
        }
    }
}

impl StreamExpConfig {
    /// CI-speed scale (sweeps the acceptance set W ∈ {1, 2, 4}).
    pub fn small() -> StreamExpConfig {
        StreamExpConfig {
            n: 256,
            d: 32,
            windows: 6,
            block: 16,
            admit_rate: 8,
            shard_counts: vec![1, 2, 4],
            seed: 0,
        }
    }
}

/// Drive `policy` through `cfg.windows` windows of `drift`, writing
/// one CSV row per window; returns the per-window order hashes (of the
/// order each boundary finalizes for the *next* window).
fn drive(
    cfg: &StreamExpConfig,
    csv: &mut CsvWriter,
    scenario: &str,
    backend: &str,
    policy: &mut StreamOrder,
    drift: &DriftPlan,
) -> Result<Vec<u32>> {
    let mut next_unit = cfg.n as u64;
    let mut hashes = Vec::with_capacity(cfg.windows);
    for window in 0..cfg.windows {
        let secs = policy.drive_window(drift, &mut next_unit, cfg.block);
        let stats = policy.stats();
        hashes.push(order_hash(policy.epoch_order(window + 1)));
        csv.row(&[
            scenario.to_string(),
            backend.to_string(),
            window.to_string(),
            policy.len().to_string(),
            fmt_f(stats.last_window_inf as f64),
            fmt_f(stats.carry_inf as f64),
            stats.admits.to_string(),
            stats.evictions.to_string(),
            stats.replans.to_string(),
            fmt_f(secs),
        ])?;
    }
    Ok(hashes)
}

/// Run the experiment and write `stream_windows.csv` to `out_dir`.
/// Fails if either contract-9 gate is violated.
pub fn run(cfg: &StreamExpConfig, out_dir: &std::path::Path) -> Result<()> {
    anyhow::ensure!(cfg.n >= 1, "need a non-empty reservoir");
    anyhow::ensure!(
        cfg.admit_rate <= cfg.n,
        "admit rate {} exceeds reservoir capacity {}",
        cfg.admit_rate,
        cfg.n
    );
    let mut csv = CsvWriter::create(
        &out_dir.join("stream_windows.csv"),
        &["scenario", "backend", "window", "live", "herd_inf",
          "carry_inf", "admits", "evictions", "replans", "order_secs"],
    )?;
    let units: Vec<u64> = (0..cfg.n as u64).collect();

    println!(
        "\nstream — sliding reservoir, n={} d={} block={} \
         admit_rate={} over {} windows:",
        cfg.n, cfg.d, cfg.block, cfg.admit_rate, cfg.windows
    );

    // ── Gate 1: the static half of contract 9. ──────────────────────
    // A prefilled reservoir with no membership events must replay a
    // bare PairBalance bit-for-bit, window for window.
    let static_plan = DriftPlan::steady(cfg.seed, 0);
    let mut static_res = StreamOrder::prefilled(cfg.n, cfg.d);
    let static_hashes = drive(
        cfg, &mut csv, "static", "unsharded", &mut static_res,
        &static_plan,
    )?;
    // The steady plan's gradients are window-independent (no shift),
    // so the PairBalance reference sees the identical static set.
    let vs: Vec<Vec<f32>> = units
        .iter()
        .map(|&u| {
            let mut g = vec![0.0f32; cfg.d];
            static_plan.grad(u, 0, &mut g);
            g
        })
        .collect();
    let mut pair = PairBalance::new(cfg.n, cfg.d);
    let mut flat = vec![0.0f32; cfg.n * cfg.d];
    let mut pair_hashes = Vec::with_capacity(cfg.windows);
    for epoch in 0..cfg.windows {
        crate::ordering::stream_static_epoch(
            &mut pair, epoch, &vs, &mut flat, cfg.block,
        );
        pair_hashes.push(order_hash(pair.epoch_order(epoch + 1)));
    }
    anyhow::ensure!(
        static_hashes == pair_hashes,
        "contract 9 (static half) violated: a static reservoir \
         diverged from PairBalance ({static_hashes:x?} vs \
         {pair_hashes:x?})"
    );
    println!(
        "  static gate: {} windows bit-equal to PairBalance",
        cfg.windows
    );

    // ── Steady churn, unsharded: the reference streaming scenario. ──
    let steady = DriftPlan::steady(cfg.seed, cfg.admit_rate);
    let mut res = StreamOrder::with_units(cfg.n, cfg.d, &units);
    drive(cfg, &mut csv, "steady", "unsharded", &mut res, &steady)?;

    // ── Gate 2: the transport half of contract 9. ───────────────────
    // The same frozen count-neutral schedule through channel and
    // loopback-TCP sharded reservoirs at every swept W: the merged
    // orders must be bit-equal per window, and no boundary may have
    // re-linked.
    for &w in &cfg.shard_counts {
        let mut chan = StreamOrder::sharded_channel(
            cfg.n, cfg.d, &units, w, 4,
        );
        let chan_hashes = drive(
            cfg, &mut csv, "steady", &format!("channel-w{w}"),
            &mut chan, &steady,
        )?;
        let mut tcp =
            StreamOrder::sharded_tcp_loopback(cfg.n, cfg.d, &units, w)?;
        let tcp_hashes = drive(
            cfg, &mut csv, "steady", &format!("tcp-w{w}"), &mut tcp,
            &steady,
        )?;
        anyhow::ensure!(
            chan_hashes == tcp_hashes,
            "contract 9 (transport half) violated at W={w}: channel \
             vs tcp orders diverged ({chan_hashes:x?} vs \
             {tcp_hashes:x?})"
        );
        anyhow::ensure!(
            chan.stats().replans == 0 && tcp.stats().replans == 0,
            "count-neutral schedule re-linked at W={w} (channel {} / \
             tcp {} replans)",
            chan.stats().replans,
            tcp.stats().replans
        );
        println!(
            "  transport gate W={w}: {} windows bit-equal \
             channel == tcp, 0 re-links",
            cfg.windows
        );
    }

    // ── Drift suite: the resize/re-link paths, recorded not gated. ──
    // Churn with retire_rate > admit_rate shrinks the reservoir every
    // boundary (on a *full* reservoir a retire deficit is topped up by
    // FIFO eviction, so only an excess of retirements resizes); bursts
    // overflow FIFO on a full reservoir (count-neutral again); shift
    // drifts the gradient distribution itself.
    let churn = DriftPlan::churn(
        cfg.seed,
        cfg.admit_rate,
        (cfg.admit_rate * 2).max(1),
    );
    let mut res = StreamOrder::with_units(cfg.n, cfg.d, &units);
    drive(cfg, &mut csv, "churn", "unsharded", &mut res, &churn)?;
    let bursty = DriftPlan::bursty(
        cfg.seed,
        cfg.admit_rate,
        2,
        cfg.admit_rate,
    );
    let mut res = StreamOrder::with_units(cfg.n, cfg.d, &units);
    drive(cfg, &mut csv, "bursty", "unsharded", &mut res, &bursty)?;
    let shift = DriftPlan {
        shift_per_window: 0.05,
        ..DriftPlan::steady(cfg.seed, cfg.admit_rate)
    };
    let mut res = StreamOrder::with_units(cfg.n, cfg.d, &units);
    drive(cfg, &mut csv, "shift", "unsharded", &mut res, &shift)?;
    csv.flush()?;

    println!(
        "  drift suite: churn/bursty/shift recorded (results: {})",
        out_dir.join("stream_windows.csv").display()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_exp_runs_and_enforces_the_contract_9_gates() {
        let tmp = crate::util::testdir::TestDir::new("stream-exp");
        let cfg = StreamExpConfig {
            n: 64,
            d: 8,
            windows: 4,
            block: 8,
            admit_rate: 4,
            shard_counts: vec![1, 2],
            seed: 3,
        };
        // run() itself enforces both contract-9 gates and fails the
        // experiment on divergence.
        run(&cfg, tmp.path()).unwrap();
        let text = std::fs::read_to_string(
            tmp.path().join("stream_windows.csv"),
        )
        .unwrap();
        // Header + windows x (static + steady-unsharded +
        // 2 backends x 2 shard counts + churn + bursty + shift).
        assert_eq!(text.lines().count(), 1 + 4 * (5 + 2 * 2));
        assert!(text.starts_with("scenario,backend,window,live"));
        // Steady churn on a full reservoir is count-neutral: the live
        // column stays at n and nothing ever re-links.
        for line in text.lines().filter(|l| l.starts_with("steady,")) {
            let cols: Vec<&str> = line.split(',').collect();
            assert_eq!(cols[3], "64", "live count drifted: {line}");
            assert_eq!(cols[8], "0", "steady schedule re-linked: {line}");
        }
        // Churn at admit 4 / retire 2 shrinks-or-grows every boundary:
        // its final row must have recorded re-plans.
        let churn_last = text
            .lines()
            .filter(|l| l.starts_with("churn,"))
            .last()
            .unwrap();
        let replans: u64 =
            churn_last.split(',').nth(8).unwrap().parse().unwrap();
        assert!(replans > 0, "churn never resized: {churn_last}");
    }
}
