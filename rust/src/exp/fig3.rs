//! Fig. 3 — fixed-order ablation: are good permutations fixed?
//!
//! Variants on the convex task (mnist/logreg) and the non-convex task
//! (cifar/LeNet):
//!   * rr, so          — baselines
//!   * grab            — full online GraB
//!   * grab-1step      — GraB during epoch 0 only, order frozen after
//!   * grab-retrain    — fresh run replaying the *final* order of a
//!                       completed GraB run (paper: works on convex, not
//!                       non-convex, because good orders track the local
//!                       optimum)

use anyhow::Result;

use crate::config::{OrderingKind, Task, TrainConfig};
use crate::runtime::Runtime;
use crate::train::Trainer;
use crate::util::ser::{fmt_f, CsvWriter};

/// Parameters of the Fig. 3 fixed-order ablation.
pub struct Fig3Config {
    /// Tasks to sweep.
    pub tasks: Vec<Task>,
    /// Epochs per run.
    pub epochs: usize,
    /// Train set size.
    pub n: usize,
    /// Eval set size.
    pub n_eval: usize,
    /// RNG seed shared by every run.
    pub seed: u64,
    /// Compiled-artifact directory.
    pub artifacts_dir: String,
}

impl Fig3Config {
    /// CI-speed scale.
    pub fn small(artifacts_dir: &str) -> Fig3Config {
        Fig3Config {
            tasks: vec![Task::Mnist, Task::Cifar],
            epochs: 10,
            n: 1024,
            n_eval: 512,
            seed: 0,
            artifacts_dir: artifacts_dir.to_string(),
        }
    }
}

/// Run the ablation and write `fig3_fixed_order.csv` to `out_dir`.
pub fn run(cfg: &Fig3Config, out_dir: &std::path::Path) -> Result<()> {
    let rt = Runtime::open(&cfg.artifacts_dir)?;
    let mut csv = CsvWriter::create(
        &out_dir.join("fig3_ablation.csv"),
        &["task", "variant", "epoch", "train_loss", "eval_loss",
          "eval_acc"],
    )?;

    for &task in &cfg.tasks {
        // Full GraB run first: both a variant and the source of the
        // retrain order.
        let mut grab_cfg = base_cfg(cfg, task, OrderingKind::GraB);
        eprintln!("[fig3] {} / grab (full)", task.name());
        let mut trainer = Trainer::new(grab_cfg.clone(), &rt, None)?;
        let grab_result = trainer.run()?;
        emit(&mut csv, task, "grab", &grab_result.epochs)?;
        let retrain_order = grab_result.final_order.clone();

        for (variant, ordering) in [
            ("rr", OrderingKind::RandomReshuffle),
            ("so", OrderingKind::ShuffleOnce),
            ("grab-1step", OrderingKind::OneStepGraB),
        ] {
            eprintln!("[fig3] {} / {variant}", task.name());
            grab_cfg = base_cfg(cfg, task, ordering);
            let mut t = Trainer::new(grab_cfg, &rt, None)?;
            let r = t.run()?;
            emit(&mut csv, task, variant, &r.epochs)?;
        }

        eprintln!("[fig3] {} / grab-retrain", task.name());
        let retrain_cfg =
            base_cfg(cfg, task, OrderingKind::RetrainFromGraB);
        let mut t =
            Trainer::new(retrain_cfg, &rt, Some(retrain_order))?;
        let r = t.run()?;
        emit(&mut csv, task, "grab-retrain", &r.epochs)?;
    }
    csv.flush()?;
    println!(
        "\nfig3 written to {}/fig3_ablation.csv \
         (paper expectation: grab-retrain ~ grab on the convex task \
         only; grab-1step underperforms both).",
        out_dir.display()
    );
    Ok(())
}

fn base_cfg(cfg: &Fig3Config, task: Task, ordering: OrderingKind)
    -> TrainConfig {
    let mut tc = TrainConfig::for_task(task);
    tc.ordering = ordering;
    tc.epochs = cfg.epochs;
    tc.n_examples = cfg.n;
    tc.n_eval = cfg.n_eval;
    tc.seed = cfg.seed;
    tc.eval_every = 1;
    tc.artifacts_dir = cfg.artifacts_dir.clone();
    tc
}

fn emit(
    csv: &mut CsvWriter,
    task: Task,
    variant: &str,
    epochs: &[crate::train::EpochMetrics],
) -> Result<()> {
    for m in epochs {
        csv.row(&[
            task.name().to_string(),
            variant.to_string(),
            m.epoch.to_string(),
            fmt_f(m.train_loss),
            m.eval_loss.map(fmt_f).unwrap_or_default(),
            m.eval_acc.map(fmt_f).unwrap_or_default(),
        ])?;
    }
    let last = epochs.last().expect("epochs");
    println!(
        "  {:<7} {:<13} final train_loss={:.4} eval_acc={:.3}",
        task.name(),
        variant,
        last.train_loss,
        last.eval_acc.unwrap_or(f64::NAN)
    );
    Ok(())
}
