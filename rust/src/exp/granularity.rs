//! Granularity ablation (paper §"On the granularity of example ordering").
//!
//! GraB's statistical gain scales as O(n^{-1/3}) in the number of ordering
//! units, so reordering groups of `gs` examples (the fallback when
//! per-example gradients are unavailable) divides effective n by gs and
//! shrinks the gap to RR. This experiment trains mnist/logreg with GraB at
//! group sizes {1, 8, 64} plus an RR baseline and reports both the loss
//! curves and the per-epoch balance bound.

use anyhow::Result;

use crate::config::{OrderingKind, Task, TrainConfig};
use crate::runtime::Runtime;
use crate::train::Trainer;
use crate::util::ser::{fmt_f, CsvWriter};

/// Parameters of the ordering-granularity sweep.
pub struct GranularityConfig {
    /// Group sizes to sweep (1 = per-example).
    pub group_sizes: Vec<usize>,
    /// Epochs per run.
    pub epochs: usize,
    /// Train set size.
    pub n: usize,
    /// Eval set size.
    pub n_eval: usize,
    /// RNG seed shared by every run.
    pub seed: u64,
    /// Compiled-artifact directory.
    pub artifacts_dir: String,
}

impl GranularityConfig {
    /// CI-speed scale.
    pub fn small(artifacts_dir: &str) -> GranularityConfig {
        GranularityConfig {
            group_sizes: vec![1, 8, 64],
            epochs: 10,
            n: 1024,
            n_eval: 512,
            seed: 0,
            artifacts_dir: artifacts_dir.to_string(),
        }
    }
}

/// Run the sweep and write `granularity.csv` to `out_dir`.
pub fn run(cfg: &GranularityConfig, out_dir: &std::path::Path)
    -> Result<()> {
    let rt = Runtime::open(&cfg.artifacts_dir)?;
    let mut csv = CsvWriter::create(
        &out_dir.join("granularity.csv"),
        &["variant", "group_size", "epoch", "train_loss", "eval_loss"],
    )?;
    let mut finals: Vec<(String, f64)> = Vec::new();

    let mut run_one = |variant: &str,
                       ordering: OrderingKind,
                       gs: usize,
                       csv: &mut CsvWriter|
     -> Result<f64> {
        let mut tc = TrainConfig::for_task(Task::Mnist);
        tc.ordering = ordering;
        tc.group_size = gs;
        tc.epochs = cfg.epochs;
        tc.n_examples = cfg.n;
        tc.n_eval = cfg.n_eval;
        tc.lr = 0.05;
        tc.seed = cfg.seed;
        tc.artifacts_dir = cfg.artifacts_dir.clone();
        eprintln!("[granularity] {variant} (gs={gs})");
        let mut t = Trainer::new(tc, &rt, None)?;
        let r = t.run()?;
        for m in &r.epochs {
            csv.row(&[
                variant.to_string(),
                gs.to_string(),
                m.epoch.to_string(),
                fmt_f(m.train_loss),
                m.eval_loss.map(fmt_f).unwrap_or_default(),
            ])?;
        }
        Ok(r.final_train_loss())
    };

    let rr = run_one("rr", OrderingKind::RandomReshuffle, 1, &mut csv)?;
    finals.push(("rr".into(), rr));
    for &gs in &cfg.group_sizes {
        let loss = run_one(
            &format!("grab-gs{gs}"),
            OrderingKind::GraB,
            gs,
            &mut csv,
        )?;
        finals.push((format!("grab-gs{gs}"), loss));
    }
    csv.flush()?;

    println!("\ngranularity — final train loss (mnist/logreg, {} epochs):",
             cfg.epochs);
    for (name, loss) in &finals {
        println!("  {name:<12} {loss:>10.4}");
    }
    println!(
        "(paper: coarser groups shrink effective n and with it GraB's \
         edge over RR — expect grab-gs1 <= grab-gs8 <= grab-gs64 ~ rr)"
    );
    Ok(())
}
