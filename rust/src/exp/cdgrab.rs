//! `grab exp cdgrab` — CD-GraB ordering-quality experiment: herding
//! bounds of PairBalance and the sharded coordinator versus GraB and
//! random reshuffling on a static gradient set, plus observe-path
//! wall-clock per policy.
//!
//! This is the ordering-core counterpart of fig1/fig4: it isolates the
//! permutation quality question ("does pair balancing without a stale
//! mean still herd?") from training dynamics, sweeping the CD-GraB shard
//! count W to show the coordinator's merge keeps the bound flat as the
//! balancing work parallelizes. Each shard count runs through both the
//! synchronous coordinator and the async worker-thread coordinator
//! (`cd-grab-wW` vs `cd-grab-wW-async`) — their herding columns must be
//! identical (the determinism contract), while their `order_secs`
//! columns show what the queue hand-off costs or saves. Writes
//! `cdgrab_herding.csv` with one row per (policy, epoch).

use anyhow::Result;

use crate::herding::herding_bound;
use crate::ordering::{GraBOrder, OrderPolicy, PairBalance, ShardedOrder};
use crate::util::prop::gen;
use crate::util::rng::Rng;
use crate::util::ser::{fmt_f, CsvWriter};

/// Parameters of the CD-GraB herding experiment.
pub struct CdGrabConfig {
    /// Number of static gradient vectors.
    pub n: usize,
    /// Gradient dimension.
    pub d: usize,
    /// Epochs (balance passes) per policy.
    pub epochs: usize,
    /// Observe block width (the simulated executor microbatch).
    pub block: usize,
    /// CD-GraB shard counts to sweep.
    pub shard_counts: Vec<usize>,
    /// RNG seed.
    pub seed: u64,
}

impl Default for CdGrabConfig {
    fn default() -> Self {
        CdGrabConfig {
            n: 4096,
            d: 256,
            epochs: 10,
            block: 64,
            shard_counts: vec![1, 4, 16],
            seed: 0,
        }
    }
}

impl CdGrabConfig {
    /// CI-speed scale.
    pub fn small() -> CdGrabConfig {
        CdGrabConfig {
            n: 1024,
            d: 64,
            epochs: 8,
            block: 32,
            shard_counts: vec![1, 4],
            seed: 0,
        }
    }
}

/// One epoch of the static set through `policy` in contiguous blocks;
/// returns (herding ℓ∞ after the epoch, observe+epoch_end seconds).
fn run_epoch(
    policy: &mut dyn OrderPolicy,
    vs: &[Vec<f32>],
    flat: &mut Vec<f32>,
    block: usize,
) -> (f32, f64) {
    let secs =
        crate::ordering::stream_static_epoch(policy, vs, flat, block);
    let (inf, _) = herding_bound(vs, policy.epoch_order(0));
    (inf, secs)
}

/// Run the experiment and write `cdgrab_herding.csv` to `out_dir`.
pub fn run(cfg: &CdGrabConfig, out_dir: &std::path::Path) -> Result<()> {
    let mut rng = Rng::new(cfg.seed);
    let vs = gen::vec_set(&mut rng, cfg.n, cfg.d);
    let mut flat = vec![0.0f32; cfg.n * cfg.d];

    let mut csv = CsvWriter::create(
        &out_dir.join("cdgrab_herding.csv"),
        &["policy", "epoch", "herd_inf", "order_secs"],
    )?;

    // Random reshuffling baseline: mean herding bound over 5 fresh
    // permutations, reported once per epoch index for plotting.
    let mut rand_acc = 0.0f32;
    for _ in 0..5 {
        let perm = rng.permutation(cfg.n);
        rand_acc += herding_bound(&vs, &perm).0;
    }
    let rand_inf = rand_acc / 5.0;
    for epoch in 0..cfg.epochs {
        csv.row(&[
            "rr".to_string(),
            epoch.to_string(),
            fmt_f(rand_inf as f64),
            fmt_f(0.0),
        ])?;
    }

    let mut policies: Vec<(String, Box<dyn OrderPolicy>)> = vec![
        (
            "grab".to_string(),
            Box::new(GraBOrder::new(
                cfg.n,
                cfg.d,
                Box::new(crate::balance::DeterministicBalancer),
            )),
        ),
        (
            "pair".to_string(),
            Box::new(PairBalance::new(cfg.n, cfg.d)),
        ),
    ];
    for &w in &cfg.shard_counts {
        policies.push((
            format!("cd-grab-w{w}"),
            Box::new(ShardedOrder::new(cfg.n, cfg.d, w)),
        ));
        policies.push((
            format!("cd-grab-w{w}-async"),
            Box::new(ShardedOrder::new_async(cfg.n, cfg.d, w, 4)),
        ));
    }

    println!(
        "\ncdgrab — herding bound, n={} d={} block={} \
         (random reshuffling baseline: {:.3}):",
        cfg.n, cfg.d, cfg.block, rand_inf
    );
    println!(
        "{:<12} {:>8} {:>12} {:>12}",
        "policy", "epoch", "herd_inf", "order(s)"
    );
    let mut finals: Vec<(String, f32)> = Vec::new();
    for (name, policy) in policies.iter_mut() {
        let mut last = f32::INFINITY;
        for epoch in 0..cfg.epochs {
            let (inf, secs) =
                run_epoch(policy.as_mut(), &vs, &mut flat, cfg.block);
            csv.row(&[
                name.clone(),
                epoch.to_string(),
                fmt_f(inf as f64),
                fmt_f(secs),
            ])?;
            last = inf;
            if epoch == cfg.epochs - 1 {
                println!(
                    "{:<12} {:>8} {:>12.4} {:>12.5}",
                    name, epoch, inf, secs
                );
            }
        }
        finals.push((name.clone(), last));
    }
    csv.flush()?;

    for (name, inf) in &finals {
        let verdict = if *inf < rand_inf { "beats" } else { "LOSES TO" };
        println!(
            "  {name}: final {inf:.4} {verdict} random ({rand_inf:.4})"
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cdgrab_runs_and_beats_random_at_small_scale() {
        let dir = std::env::temp_dir().join("grab_cdgrab_test");
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = CdGrabConfig {
            n: 256,
            d: 16,
            epochs: 6,
            block: 16,
            shard_counts: vec![1, 4],
            seed: 1,
        };
        run(&cfg, &dir).unwrap();
        let text = std::fs::read_to_string(
            dir.join("cdgrab_herding.csv")).unwrap();
        // Header + rr + grab + pair + (sync, async) x two shard
        // counts, 6 epochs each.
        assert_eq!(text.lines().count(), 1 + 7 * 6);
        // Determinism contract: sync and async coordinators must report
        // identical herding bounds at every (w, epoch).
        fn herd_col<'t>(text: &'t str, name: &str) -> Vec<&'t str> {
            let prefix = format!("{name},");
            text.lines()
                .filter(|l| l.starts_with(&prefix))
                .map(|l| l.split(',').nth(2).unwrap())
                .collect()
        }
        for w in [1, 4] {
            let sync = herd_col(&text, &format!("cd-grab-w{w}"));
            let asynch =
                herd_col(&text, &format!("cd-grab-w{w}-async"));
            assert_eq!(sync.len(), 6);
            assert_eq!(
                sync, asynch,
                "sync vs async herding diverged at w={w}"
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
