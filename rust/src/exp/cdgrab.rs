//! `grab exp cdgrab` — CD-GraB ordering-quality experiment: herding
//! bounds of PairBalance and the sharded coordinator versus GraB and
//! random reshuffling on a static gradient set, plus observe-path
//! wall-clock per policy.
//!
//! This is the ordering-core counterpart of fig1/fig4: it isolates the
//! permutation quality question ("does pair balancing without a stale
//! mean still herd?") from training dynamics, sweeping the CD-GraB shard
//! count W to show the coordinator's merge keeps the bound flat as the
//! balancing work parallelizes. Each shard count runs through the
//! synchronous coordinator, the async worker-thread coordinator, and the
//! TCP socket coordinator (`cd-grab-wW` vs `-wW-async` vs `-wW-tcp`) —
//! their herding columns must be identical (determinism contracts 3 and
//! 5, asserted by the run itself), while the `order_secs`, `stalls`, and
//! `wire_bytes` columns show what each transport costs. Writes
//! `cdgrab_herding.csv` with one row per (policy, epoch); the `stalls` /
//! `wire_bytes` columns are cumulative link counters at the end of that
//! epoch (0 for un-transported policies).
//!
//! Beyond the equal-weight sweep, the run covers the topology layer:
//! a skewed static topology (weights 1:1:4) through all three
//! dispatch paths — their herding columns must also be identical
//! (weighted contract-6 gate) — and a measured-elastic channel
//! coordinator whose per-epoch plan lands in the new `shards` /
//! `weights` CSV columns, the exact record needed to replay an elastic
//! run as a `--weights`-pinned static one.
//!
//! Distributed modes: `--listen ADDR` turns this process into a blocking
//! shard worker server (no sweep); `--connect ADDR[,ADDR…]` makes the
//! sweep's TCP policies dial those server(s) instead of spawning
//! in-process loopback workers.

use anyhow::Result;

use crate::herding::herding_bound;
use crate::ordering::{GraBOrder, OrderPolicy, PairBalance, ShardedOrder};
use crate::train::checkpoint;
use crate::util::prop::gen;
use crate::util::rng::Rng;
use crate::util::ser::{fmt_f, CsvWriter};

/// The skewed static topology demonstrated (and gated) by the sweep.
const SKEW_WEIGHTS: [u64; 3] = [1, 1, 4];

/// Parameters of the CD-GraB herding experiment.
pub struct CdGrabConfig {
    /// Number of static gradient vectors.
    pub n: usize,
    /// Gradient dimension.
    pub d: usize,
    /// Epochs (balance passes) per policy.
    pub epochs: usize,
    /// Observe block width (the simulated executor microbatch).
    pub block: usize,
    /// CD-GraB shard counts to sweep.
    pub shard_counts: Vec<usize>,
    /// RNG seed.
    pub seed: u64,
    /// Remote worker server(s) for the TCP policies (`--connect`,
    /// comma-separated for a pool); `None` spawns in-process loopback
    /// workers.
    pub connect: Option<String>,
    /// Per-frame read timeout (seconds) on remote worker links
    /// (`--read-timeout`); ignored for loopback/in-process backends.
    pub read_timeout_secs: u64,
    /// Durable run root (`--checkpoint-dir`): each policy snapshots its
    /// ordering state into `<dir>/<policy>/` after each epoch.
    pub checkpoint_dir: Option<String>,
    /// Snapshot cadence in epochs (`--checkpoint-every`, default 1).
    pub checkpoint_every: usize,
    /// Resume each policy from its latest snapshot (`--resume`); the
    /// rewritten CSV then covers only the remaining epochs.
    pub resume: bool,
}

impl Default for CdGrabConfig {
    fn default() -> Self {
        CdGrabConfig {
            n: 4096,
            d: 256,
            epochs: 10,
            block: 64,
            shard_counts: vec![1, 4, 16],
            seed: 0,
            connect: None,
            read_timeout_secs:
                crate::ordering::transport::tcp::DEFAULT_READ_TIMEOUT_SECS,
            checkpoint_dir: None,
            checkpoint_every: 1,
            resume: false,
        }
    }
}

impl CdGrabConfig {
    /// CI-speed scale (sweeps the acceptance set W ∈ {1, 2, 4}).
    pub fn small() -> CdGrabConfig {
        CdGrabConfig {
            n: 1024,
            d: 64,
            epochs: 8,
            block: 32,
            shard_counts: vec![1, 2, 4],
            seed: 0,
            connect: None,
            read_timeout_secs:
                crate::ordering::transport::tcp::DEFAULT_READ_TIMEOUT_SECS,
            checkpoint_dir: None,
            checkpoint_every: 1,
            resume: false,
        }
    }

    /// Sweep identity for the run-directory fingerprint gate
    /// (docs/determinism.md contract 8). `epochs` is deliberately
    /// excluded — it is a resumable horizon, and extending it is the
    /// point of resuming — as are `connect` and `read_timeout_secs`
    /// (contract 5: the transport never shifts results).
    pub fn fingerprint(&self) -> u32 {
        let shards: Vec<String> =
            self.shard_counts.iter().map(|w| w.to_string()).collect();
        let canon = format!(
            "cdgrab;n={};d={};block={};shard_counts={};seed={}",
            self.n,
            self.d,
            self.block,
            shards.join(":"),
            self.seed
        );
        crate::util::ser::fnv1a32(canon.as_bytes())
    }
}

/// One epoch of the static set through `policy` in contiguous blocks;
/// returns (herding ℓ∞ after the epoch, observe+epoch_end seconds).
fn run_epoch(
    policy: &mut dyn OrderPolicy,
    epoch: usize,
    vs: &[Vec<f32>],
    flat: &mut Vec<f32>,
    block: usize,
) -> (f32, f64) {
    let secs = crate::ordering::stream_static_epoch(
        policy, epoch, vs, flat, block,
    );
    // The order just finalized for the *next* epoch is what the
    // herding gate scores.
    let (inf, _) = herding_bound(vs, policy.epoch_order(epoch + 1));
    (inf, secs)
}

/// Run the experiment and write `cdgrab_herding.csv` to `out_dir`.
/// Fails if any transport's herding column diverges from the
/// synchronous coordinator's at the same shard count (the determinism
/// gate).
pub fn run(cfg: &CdGrabConfig, out_dir: &std::path::Path) -> Result<()> {
    let mut rng = Rng::new(cfg.seed);
    let vs = gen::vec_set(&mut rng, cfg.n, cfg.d);
    let mut flat = vec![0.0f32; cfg.n * cfg.d];

    let mut csv = CsvWriter::create(
        &out_dir.join("cdgrab_herding.csv"),
        &["policy", "epoch", "herd_inf", "order_secs", "stalls",
          "wire_bytes", "shards", "weights"],
    )?;
    let addrs: Option<Vec<String>> = cfg
        .connect
        .as_ref()
        .map(|s| crate::ordering::transport::parse_connect_addrs(s));

    // Random reshuffling baseline: mean herding bound over 5 fresh
    // permutations, reported once per epoch index for plotting.
    let mut rand_acc = 0.0f32;
    for _ in 0..5 {
        let perm = rng.permutation(cfg.n);
        rand_acc += herding_bound(&vs, &perm).0;
    }
    let rand_inf = rand_acc / 5.0;
    for epoch in 0..cfg.epochs {
        csv.row(&[
            "rr".to_string(),
            epoch.to_string(),
            fmt_f(rand_inf as f64),
            fmt_f(0.0),
            "0".to_string(),
            "0".to_string(),
            String::new(),
            String::new(),
        ])?;
    }

    let mut policies: Vec<(String, Box<dyn OrderPolicy>)> = vec![
        (
            "grab".to_string(),
            Box::new(GraBOrder::new(
                cfg.n,
                cfg.d,
                Box::new(crate::balance::DeterministicBalancer),
            )),
        ),
        (
            "pair".to_string(),
            Box::new(PairBalance::new(cfg.n, cfg.d)),
        ),
    ];
    for &w in &cfg.shard_counts {
        policies.push((
            format!("cd-grab-w{w}"),
            Box::new(ShardedOrder::new(cfg.n, cfg.d, w)),
        ));
        policies.push((
            format!("cd-grab-w{w}-async"),
            Box::new(ShardedOrder::new_async(cfg.n, cfg.d, w, 4)),
        ));
        let tcp: Box<dyn OrderPolicy> = match &addrs {
            Some(addrs) => {
                Box::new(ShardedOrder::new_tcp_connect_weighted(
                    addrs,
                    cfg.n,
                    cfg.d,
                    &vec![1; w],
                    std::time::Duration::from_secs(cfg.read_timeout_secs),
                )?)
            }
            None => {
                Box::new(ShardedOrder::new_tcp_loopback(cfg.n, cfg.d, w)?)
            }
        };
        policies.push((format!("cd-grab-w{w}-tcp"), tcp));
    }
    // Weighted topology trio (skew 1:1:4): the three dispatch paths
    // must agree on an uneven split too (weighted contract-6 gate).
    policies.push((
        "cd-grab-skew114".to_string(),
        Box::new(ShardedOrder::new_weighted(cfg.n, cfg.d, &SKEW_WEIGHTS)),
    ));
    policies.push((
        "cd-grab-skew114-async".to_string(),
        Box::new(ShardedOrder::new_async_weighted(
            cfg.n,
            cfg.d,
            &SKEW_WEIGHTS,
            4,
        )),
    ));
    let skew_tcp: Box<dyn OrderPolicy> = match &addrs {
        Some(addrs) => Box::new(ShardedOrder::new_tcp_connect_weighted(
            addrs,
            cfg.n,
            cfg.d,
            &SKEW_WEIGHTS,
            std::time::Duration::from_secs(cfg.read_timeout_secs),
        )?),
        None => Box::new(ShardedOrder::new_tcp_loopback_weighted(
            cfg.n,
            cfg.d,
            &SKEW_WEIGHTS,
        )?),
    };
    policies.push(("cd-grab-skew114-tcp".to_string(), skew_tcp));
    // A measured-elastic coordinator: its per-epoch plan (usually
    // frozen at equal weights on a healthy machine) lands in the
    // shards/weights columns — the replay record for elastic runs.
    policies.push((
        "cd-grab-w2-elastic".to_string(),
        Box::new(ShardedOrder::new_elastic(cfg.n, cfg.d, &[1, 1], 4)),
    ));

    println!(
        "\ncdgrab — herding bound, n={} d={} block={} \
         (random reshuffling baseline: {:.3}):",
        cfg.n, cfg.d, cfg.block, rand_inf
    );
    println!(
        "{:<18} {:>8} {:>12} {:>12} {:>8} {:>12}",
        "policy", "epoch", "herd_inf", "order(s)", "stalls", "wire_b"
    );
    // Per-policy herding column, kept for the cross-transport equality
    // assertion below.
    let ckpt_root =
        cfg.checkpoint_dir.as_ref().map(std::path::PathBuf::from);
    let mut herd_cols: Vec<(String, Vec<f32>)> = Vec::new();
    for (name, policy) in policies.iter_mut() {
        // Durable-run layer (contract 8): one run directory per policy
        // under --checkpoint-dir; on --resume, restore the policy's
        // epoch-boundary state and re-run only the remaining epochs.
        let mut start = 0usize;
        let run_dir = match &ckpt_root {
            None => None,
            Some(root) => {
                let dir = root.join(name.as_str());
                let rd = if cfg.resume
                    && dir.join(checkpoint::MANIFEST_FILE).is_file()
                {
                    let rd = checkpoint::RunDir::open(&dir)?;
                    rd.check_fingerprint(cfg.fingerprint())?;
                    anyhow::ensure!(
                        rd.manifest.policy == *name,
                        "run directory {} belongs to policy {:?}, \
                         not {:?}",
                        dir.display(),
                        rd.manifest.policy,
                        name
                    );
                    if let Some(ckpt) = rd.load_latest()? {
                        // Same typed resume gate as the trainer
                        // (PolicyNotResumable instead of a silent
                        // ordering restart).
                        checkpoint::restore_policy(
                            policy.as_mut(),
                            &ckpt,
                        )
                        .map_err(|e| {
                            anyhow::anyhow!("resuming {name}: {e}")
                        })?;
                        start = ckpt.epoch as usize + 1;
                        eprintln!(
                            "[cdgrab] {name}: resumed after epoch {} \
                             from {}",
                            ckpt.epoch,
                            dir.display()
                        );
                    }
                    rd
                } else {
                    checkpoint::RunDir::create(
                        &dir,
                        checkpoint::manifest_for(
                            cfg.fingerprint(),
                            &format!(
                                "cdgrab-n{}-d{}-s{}",
                                cfg.n, cfg.d, cfg.seed
                            ),
                            name,
                            crate::tensor::default_kernel().name(),
                            cfg.checkpoint_every as u64,
                        ),
                    )?
                };
                Some(rd)
            }
        };
        let mut col = Vec::with_capacity(cfg.epochs);
        for epoch in start..cfg.epochs {
            let (inf, secs) = run_epoch(
                policy.as_mut(), epoch, &vs, &mut flat, cfg.block,
            );
            let link = policy
                .transport_stats()
                .map(|s| s.total())
                .unwrap_or_default();
            // The plan that produced this epoch's order (entry `epoch`
            // of the policy's topology log) — the replay columns.
            let (shards_col, weights_col) = policy
                .topology_log()
                .and_then(|log| log.get(epoch))
                .map(|t| {
                    (t.num_shards().to_string(), t.weights_label())
                })
                .unwrap_or_default();
            csv.row(&[
                name.clone(),
                epoch.to_string(),
                fmt_f(inf as f64),
                fmt_f(secs),
                link.stalls.to_string(),
                (link.tx_bytes + link.rx_bytes).to_string(),
                shards_col,
                weights_col,
            ])?;
            col.push(inf);
            if epoch == cfg.epochs - 1 {
                println!(
                    "{:<18} {:>8} {:>12.4} {:>12.5} {:>8} {:>12}",
                    name,
                    epoch,
                    inf,
                    secs,
                    link.stalls,
                    link.tx_bytes + link.rx_bytes
                );
            }
            // Snapshot the policy's epoch-boundary state (its next
            // permutation is already materialized — epoch_order is
            // idempotent at a boundary, so this never perturbs the
            // run).
            if let Some(rd) = &run_dir {
                if (epoch + 1) % cfg.checkpoint_every.max(1) == 0
                    || epoch + 1 == cfg.epochs
                {
                    let order: Vec<u64> = policy
                        .epoch_order(0)
                        .iter()
                        .map(|&v| v as u64)
                        .collect();
                    rd.save_epoch(
                        &checkpoint::Checkpoint {
                            epoch: epoch as u64,
                            params: Vec::new(),
                            velocity: Vec::new(),
                            order,
                            sched: None,
                            policy_state: policy.save_state(),
                        },
                        checkpoint::DEFAULT_KEEP_LAST,
                    )?;
                }
            }
        }
        herd_cols.push((name.clone(), col));
    }
    csv.flush()?;

    // Determinism gate (contracts 3 and 5): for every swept W, the
    // async and tcp transports must reproduce the synchronous
    // coordinator's herding column exactly, every epoch.
    fn col<'h>(
        cols: &'h [(String, Vec<f32>)],
        name: &str,
    ) -> &'h [f32] {
        cols.iter()
            .find(|(n, _)| n == name)
            .map(|(_, c)| c.as_slice())
            .expect("policy column")
    }
    for &w in &cfg.shard_counts {
        let sync = col(&herd_cols, &format!("cd-grab-w{w}"));
        for variant in ["async", "tcp"] {
            let other =
                col(&herd_cols, &format!("cd-grab-w{w}-{variant}"));
            if sync.len() != other.len() {
                // A resumed run after a mid-sweep crash leaves the
                // policies at different epochs; the cross-transport
                // gate only applies over a common epoch range.
                eprintln!(
                    "[cdgrab] gate skipped: cd-grab-w{w} vs -{variant} \
                     resumed at different epochs"
                );
                continue;
            }
            anyhow::ensure!(
                sync == other,
                "herding diverged: cd-grab-w{w} vs -{variant} \
                 ({sync:?} vs {other:?})"
            );
        }
    }
    // Weighted gate: the skewed topology must agree across dispatch
    // paths just like the equal splits.
    let skew_sync = col(&herd_cols, "cd-grab-skew114");
    for variant in ["async", "tcp"] {
        let other =
            col(&herd_cols, &format!("cd-grab-skew114-{variant}"));
        if skew_sync.len() != other.len() {
            eprintln!(
                "[cdgrab] gate skipped: cd-grab-skew114 vs -{variant} \
                 resumed at different epochs"
            );
            continue;
        }
        anyhow::ensure!(
            skew_sync == other,
            "herding diverged: cd-grab-skew114 vs -{variant} \
             ({skew_sync:?} vs {other:?})"
        );
    }
    println!(
        "  determinism gate: sync == async == tcp herding columns at \
         W in {:?} and at weights 1:1:4",
        cfg.shard_counts
    );

    for (name, col) in &herd_cols {
        // A resumed, already-finished policy runs zero epochs here.
        let Some(&inf) = col.last() else { continue };
        let verdict = if inf < rand_inf { "beats" } else { "LOSES TO" };
        println!(
            "  {name}: final {inf:.4} {verdict} random ({rand_inf:.4})"
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_cfg() -> CdGrabConfig {
        CdGrabConfig {
            n: 256,
            d: 16,
            epochs: 6,
            block: 16,
            shard_counts: vec![1, 4],
            seed: 1,
            ..CdGrabConfig::small()
        }
    }

    #[test]
    fn cdgrab_runs_and_beats_random_at_small_scale() {
        let tmp = crate::util::testdir::TestDir::new("cdgrab-exp");
        let dir = tmp.path().to_path_buf();
        let cfg = test_cfg();
        // run() itself enforces the sync == async == tcp herding gate
        // and fails the experiment on divergence.
        run(&cfg, &dir).unwrap();
        let text = std::fs::read_to_string(
            dir.join("cdgrab_herding.csv")).unwrap();
        // Header + rr + grab + pair + (sync, async, tcp) x two shard
        // counts + the skew trio + the elastic policy, 6 epochs each.
        assert_eq!(text.lines().count(), 1 + 13 * 6);
        // Determinism contract: the transports must report identical
        // herding bounds at every (w, epoch).
        fn herd_col<'t>(text: &'t str, name: &str) -> Vec<&'t str> {
            let prefix = format!("{name},");
            text.lines()
                .filter(|l| l.starts_with(&prefix))
                .map(|l| l.split(',').nth(2).unwrap())
                .collect()
        }
        for w in [1, 4] {
            let sync = herd_col(&text, &format!("cd-grab-w{w}"));
            let asynch =
                herd_col(&text, &format!("cd-grab-w{w}-async"));
            let tcp = herd_col(&text, &format!("cd-grab-w{w}-tcp"));
            assert_eq!(sync.len(), 6);
            assert_eq!(
                sync, asynch,
                "sync vs async herding diverged at w={w}"
            );
            assert_eq!(
                sync, tcp,
                "sync vs tcp herding diverged at w={w}"
            );
        }
        // The skew trio must agree too (weighted contract-6 gate).
        let skew_sync = herd_col(&text, "cd-grab-skew114");
        assert_eq!(skew_sync.len(), 6);
        assert_eq!(
            skew_sync,
            herd_col(&text, "cd-grab-skew114-async"),
            "skewed sync vs async herding diverged"
        );
        assert_eq!(
            skew_sync,
            herd_col(&text, "cd-grab-skew114-tcp"),
            "skewed sync vs tcp herding diverged"
        );
        // Topology replay columns: the skew rows record 3 shards at
        // weights 1:1:4, and the elastic rows carry a weights label.
        let skew_row = text
            .lines()
            .find(|l| l.starts_with("cd-grab-skew114,"))
            .unwrap();
        let cols: Vec<&str> = skew_row.split(',').collect();
        assert_eq!(cols[6], "3", "shards column: {skew_row}");
        assert_eq!(cols[7], "1:1:4", "weights column: {skew_row}");
        let elastic_row = text
            .lines()
            .find(|l| l.starts_with("cd-grab-w2-elastic,"))
            .unwrap();
        let cols: Vec<&str> = elastic_row.split(',').collect();
        assert!(!cols[7].is_empty(), "elastic weights column empty");
        // Unsharded rows leave the topology columns blank.
        let pair_row =
            text.lines().find(|l| l.starts_with("pair,")).unwrap();
        assert!(pair_row.ends_with(",,"), "pair row: {pair_row}");
        // The socket policies must actually have moved bytes.
        let tcp_rows: Vec<&str> = text
            .lines()
            .filter(|l| l.starts_with("cd-grab-w4-tcp,"))
            .collect();
        let wire: u64 = tcp_rows
            .last()
            .unwrap()
            .split(',')
            .nth(5)
            .unwrap()
            .parse()
            .unwrap();
        assert!(wire > 0, "tcp policy reported no wire bytes");
    }

    /// Contract 8 at the experiment layer: a sweep killed after epoch
    /// e and resumed from its run directory emits herding values for
    /// the remaining epochs bit-equal to an uninterrupted sweep.
    #[test]
    fn cdgrab_resume_matches_uninterrupted_sweep() {
        fn herd_rows(text: &str) -> Vec<(String, String, String)> {
            text.lines()
                .skip(1)
                .map(|l| {
                    let mut it = l.split(',');
                    (
                        it.next().unwrap().to_string(),
                        it.next().unwrap().to_string(),
                        it.next().unwrap().to_string(),
                    )
                })
                .collect()
        }

        // Uninterrupted reference sweep.
        let full_tmp =
            crate::util::testdir::TestDir::new("cdgrab-resume-full");
        let mut cfg = test_cfg();
        cfg.shard_counts = vec![2];
        run(&cfg, full_tmp.path()).unwrap();
        let full = std::fs::read_to_string(
            full_tmp.path().join("cdgrab_herding.csv"),
        )
        .unwrap();

        // "Crashed" sweep: same config stopped three epochs early,
        // snapshotting every epoch...
        let part_tmp =
            crate::util::testdir::TestDir::new("cdgrab-resume-part");
        let ckpt = part_tmp.path().join("ckpt");
        let mut partial = test_cfg();
        partial.shard_counts = vec![2];
        partial.epochs = 3;
        partial.checkpoint_dir =
            Some(ckpt.to_string_lossy().into_owned());
        run(&partial, part_tmp.path()).unwrap();

        // ...then resumed out to the full horizon from fresh policy
        // objects seeded only by the run directories.
        let mut resumed = test_cfg();
        resumed.shard_counts = vec![2];
        resumed.checkpoint_dir =
            Some(ckpt.to_string_lossy().into_owned());
        resumed.resume = true;
        run(&resumed, part_tmp.path()).unwrap();
        let tail = std::fs::read_to_string(
            part_tmp.path().join("cdgrab_herding.csv"),
        )
        .unwrap();

        // Every resumed (policy, epoch) herding value must match the
        // uninterrupted sweep exactly (epochs 3..6; `rr` re-emits all
        // epochs, which the full run also contains).
        let full_rows = herd_rows(&full);
        let tail_rows = herd_rows(&tail);
        assert!(
            tail_rows.iter().any(|(_, e, _)| e == "3"),
            "resumed sweep emitted no tail epochs"
        );
        // The measured-elastic policy is excluded: although a resume
        // now carries the planner's EWMA (so the resumed process plans
        // from the same smoothed history — see
        // `elastic_snapshot_carries_the_planner_ewma`), the costs the
        // two sweeps *measure after* the boundary are wall-clock and
        // can differ, so herding equality is not guaranteed row-for-row
        // — the documented contract-8 carve-out.
        for row in tail_rows
            .iter()
            .filter(|(p, _, _)| !p.contains("elastic"))
        {
            assert!(
                full_rows.contains(row),
                "resumed row {row:?} not in the uninterrupted sweep"
            );
        }

        // A config whose fingerprint differs must be refused.
        let mut other = test_cfg();
        other.shard_counts = vec![2];
        other.seed = 99;
        other.checkpoint_dir =
            Some(ckpt.to_string_lossy().into_owned());
        other.resume = true;
        let err = run(&other, part_tmp.path()).unwrap_err();
        assert!(
            format!("{err:#}").contains("fingerprint"),
            "wanted a fingerprint refusal, got: {err:#}"
        );
    }
}
