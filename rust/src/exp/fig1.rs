//! Fig. 1b — prefix-sum norms of n random vectors in [0,1]^128 under
//! different orderings: the original (random) order, one balance+reorder
//! pass (Algorithm 5 + Algorithm 3), fully herded (repeated passes), and
//! greedy (Algorithm 1), plotted as ‖Σ_{t≤k}(z_σ(t) − mean)‖₂ vs k.

use anyhow::Result;

use crate::balance::DeterministicBalancer;
use crate::herding::offline::herd;
use crate::herding::{greedy::greedy_order, prefix_trajectory};
use crate::util::rng::Rng;
use crate::util::ser::{fmt_f, CsvWriter};

/// Parameters of the Fig. 1b prefix-norm experiment.
pub struct Fig1Config {
    /// Number of random vectors.
    pub n: usize,
    /// Vector dimension.
    pub d: usize,
    /// Balance+reorder passes for the "herded" series.
    pub herd_passes: usize,
    /// Write every `stride`-th k to keep the CSV small.
    pub stride: usize,
    /// RNG seed.
    pub seed: u64,
    /// Skip greedy above this n (O(n²d) gets slow).
    pub greedy_max_n: usize,
}

impl Default for Fig1Config {
    fn default() -> Self {
        Fig1Config {
            n: 10_000,
            d: 128,
            herd_passes: 10,
            stride: 20,
            seed: 0,
            greedy_max_n: 4000,
        }
    }
}

/// Run the experiment and write `fig1_prefix_norms.csv` to `out_dir`.
pub fn run(cfg: &Fig1Config, out_dir: &std::path::Path) -> Result<()> {
    let mut rng = Rng::new(cfg.seed);
    // z_i ~ U[0, 1]^d, exactly the paper's toy setup.
    let vs: Vec<Vec<f32>> = (0..cfg.n)
        .map(|_| (0..cfg.d).map(|_| rng.f32()).collect())
        .collect();
    let original: Vec<usize> = (0..cfg.n).collect();

    let mut b = DeterministicBalancer;
    let (one_pass, _) = herd(&mut b, &vs, 1);
    let (herded, _) = herd(&mut b, &vs, cfg.herd_passes);

    let mut series: Vec<(&str, Vec<f32>)> = vec![
        ("original", prefix_trajectory(&vs, &original)),
        ("balance_1pass", prefix_trajectory(&vs, &one_pass)),
        ("herded", prefix_trajectory(&vs, &herded)),
    ];
    if cfg.n <= cfg.greedy_max_n {
        let g = greedy_order(&vs);
        series.push(("greedy", prefix_trajectory(&vs, &g)));
    }

    let mut csv = CsvWriter::create(
        &out_dir.join("fig1_prefix_norms.csv"),
        &["order", "k", "prefix_l2"],
    )?;
    for (name, traj) in &series {
        for (k, v) in traj.iter().enumerate() {
            if k % cfg.stride == 0 || k + 1 == traj.len() {
                csv.row(&[
                    name.to_string(),
                    (k + 1).to_string(),
                    fmt_f(*v as f64),
                ])?;
            }
        }
    }
    csv.flush()?;

    println!("\nfig1 — max prefix-sum L2 norm (n={}, d={}):", cfg.n, cfg.d);
    for (name, traj) in &series {
        let max = traj.iter().cloned().fold(0.0f32, f32::max);
        println!("  {name:<14} {max:>12.3}");
    }
    println!("(paper: balanced/herded orders flatten the prefix curve vs \
              the original order; see results/fig1_prefix_norms.csv)");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_small_runs_and_orders_win() {
        let dir = std::env::temp_dir().join("grab_fig1_test");
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = Fig1Config {
            n: 400,
            d: 16,
            herd_passes: 5,
            stride: 10,
            seed: 1,
            greedy_max_n: 400,
        };
        run(&cfg, &dir).unwrap();
        let text =
            std::fs::read_to_string(dir.join("fig1_prefix_norms.csv"))
                .unwrap();
        assert!(text.lines().count() > 10);
        assert!(text.contains("herded"));
        assert!(text.contains("greedy"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
