//! Statement 1 — greedy ordering is Ω(n) on the Chelidze et al.
//! construction while random reshuffling is O(√n) on average.
//!
//! Sweeps n, evaluates the herding objective (Eq. 2) under (a) greedy on
//! raw vectors (the construction analysed in Appendix B.1), (b) greedy on
//! centered vectors (Algorithm 1 as stated — centering happens to rescue
//! this instance, which we report), (c) random permutations, and fits
//! log-log scaling exponents.

use anyhow::Result;

use crate::herding::adversarial::adversarial_vectors;
use crate::herding::greedy::{greedy_order, greedy_order_raw};
use crate::herding::herding_bound;
use crate::util::rng::Rng;
use crate::util::ser::{fmt_f, CsvWriter};
use crate::util::stats::scaling_exponent;

/// Parameters of the Statement 1 adversarial-scaling experiment.
pub struct Statement1Config {
    /// Problem sizes to sweep.
    pub ns: Vec<usize>,
    /// Random permutations averaged per n.
    pub random_trials: usize,
    /// RNG seed.
    pub seed: u64,
}

impl Default for Statement1Config {
    fn default() -> Self {
        Statement1Config {
            ns: vec![64, 128, 256, 512, 1024, 2048],
            random_trials: 10,
            seed: 0,
        }
    }
}

/// Run the experiment and write `statement1_adversarial.csv`.
pub fn run(cfg: &Statement1Config, out_dir: &std::path::Path)
    -> Result<()> {
    let mut csv = CsvWriter::create(
        &out_dir.join("statement1_adversarial.csv"),
        &["order", "n", "herding_l2"],
    )?;
    let mut rng = Rng::new(cfg.seed);
    let mut greedy_raw = Vec::new();
    let mut greedy_centered = Vec::new();
    let mut random = Vec::new();
    for &n in &cfg.ns {
        let vs = adversarial_vectors(n);
        let g_raw = herding_bound(&vs, &greedy_order_raw(&vs)).1 as f64;
        let g_cen = herding_bound(&vs, &greedy_order(&vs)).1 as f64;
        let mut acc = 0.0;
        for _ in 0..cfg.random_trials {
            acc += herding_bound(&vs, &rng.permutation(n)).1 as f64;
        }
        let r = acc / cfg.random_trials as f64;
        for (name, v) in [
            ("greedy_raw", g_raw),
            ("greedy_centered", g_cen),
            ("random", r),
        ] {
            csv.row(&[name.to_string(), n.to_string(), fmt_f(v)])?;
        }
        greedy_raw.push(g_raw);
        greedy_centered.push(g_cen);
        random.push(r);
    }
    csv.flush()?;

    let xs: Vec<f64> = cfg.ns.iter().map(|&n| n as f64).collect();
    let e_raw = scaling_exponent(&xs, &greedy_raw);
    let e_rand = scaling_exponent(&xs, &random);
    println!("\nstatement1 — herding objective on the adversarial family:");
    println!("{:>8} {:>14} {:>17} {:>12}", "n", "greedy_raw",
             "greedy_centered", "random");
    for (i, &n) in cfg.ns.iter().enumerate() {
        println!(
            "{:>8} {:>14.2} {:>17.2} {:>12.2}",
            n, greedy_raw[i], greedy_centered[i], random[i]
        );
    }
    println!(
        "  scaling: greedy_raw ~ n^{e_raw:.2} (paper: Ω(n)), \
         random ~ n^{e_rand:.2} (paper: O(√n))"
    );
    println!(
        "  note: pre-centering (Alg. 1 line 2) happens to fix this \
         specific instance — greedy_centered stays O(1) here; the \
         Ω(n) failure is the uncentered greedy of the B.1 proof."
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn statement1_runs_and_separates() {
        let dir = std::env::temp_dir().join("grab_stmt1_test");
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = Statement1Config {
            ns: vec![64, 128, 256],
            random_trials: 3,
            seed: 1,
        };
        run(&cfg, &dir).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }
}
