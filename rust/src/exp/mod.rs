//! Experiment harness — one module per paper artifact, each writing a CSV
//! under `results/` and printing the paper's rows/series. See DESIGN.md §5
//! for the full experiment index.
//!
//! ```bash
//! grab exp fig1        # Fig. 1b prefix-norm curves
//! grab exp fig2        # Fig. 2 training/validation across orderings
//! grab exp fig3        # Fig. 3 fixed-order ablation
//! grab exp fig4        # Fig. 4 Alg. 5 vs Alg. 6 herding bounds
//! grab exp table1      # Table 1 measured compute/storage overhead
//! grab exp statement1  # Statement 1 greedy vs random scaling
//! grab exp cdgrab      # CD-GraB pair/sharded herding bounds
//! grab exp stream      # sliding-reservoir streaming (contract 9)
//! grab exp all         # everything, small scale
//! ```

pub mod cdgrab;
pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod granularity;
pub mod statement1;
pub mod stream;
pub mod table1;

use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::config::Task;
use crate::util::cli::Args;

/// Dispatch `grab exp <id>`.
pub fn run_from_cli(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all")
        .to_string();
    let out = PathBuf::from(args.str_or("out", "results"));
    std::fs::create_dir_all(&out)?;
    let scale = args.str_or("scale", "small");
    let artifacts = args.str_or("artifacts", "artifacts");
    let paper_scale = match scale.as_str() {
        "small" => false,
        "paper" => true,
        other => bail!("unknown --scale {other:?} (small|paper)"),
    };
    let task_filter = args.opt_str("task");
    let epochs = args.usize_or("epochs", 0)?; // 0 = scale default
    let n = args.usize_or("n", 0)?;
    // Distributed CD-GraB modes (cdgrab only): --listen turns this
    // process into a shard worker server; --connect points the sweep's
    // TCP policies at one.
    let listen = args.opt_str("listen");
    let connect = args.opt_str("connect");
    let max_conns = args.usize_or("max-conns", 0)?; // 0 = serve forever
    let read_timeout = args.usize_or(
        "read-timeout",
        crate::ordering::transport::tcp::DEFAULT_READ_TIMEOUT_SECS
            as usize,
    )? as u64;
    // Order-service modes (cdgrab only): --register turns this process
    // into a worker that dials a `grab serve` daemon and waits to be
    // leased to jobs; --service submits the sweep to a daemon instead
    // of dialing workers directly.
    let register = args.opt_str("register");
    let service = args.opt_str("service");
    // Durable-run flags (cdgrab only): per-policy run directories with
    // epoch snapshots (docs/determinism.md contract 8).
    let checkpoint_dir = args.opt_str("checkpoint-dir");
    let checkpoint_every = args.usize_or("checkpoint-every", 1)?;
    if args.opt_str("resume").is_some() {
        bail!("--resume is a boolean flag and takes no value");
    }
    let resume = args.flag("resume");
    // Streaming flag (stream only): fresh admits per window on the
    // churn schedules.
    let admit_rate = match args.opt_str("admit-rate") {
        Some(s) => Some(s.parse::<usize>().map_err(|_| {
            anyhow::anyhow!("--admit-rate wants an integer, got {s:?}")
        })?),
        None => None,
    };
    args.reject_unknown()?;
    anyhow::ensure!(
        admit_rate.is_none() || id == "stream",
        "--admit-rate only applies to `exp stream`"
    );
    anyhow::ensure!(
        [listen.is_some(), connect.is_some(), register.is_some(),
         service.is_some()]
            .iter()
            .filter(|&&b| b)
            .count()
            <= 1,
        "--listen (serve shard workers), --connect (dial a worker \
         server), --register (join an order-service daemon), and \
         --service (submit to a daemon) are mutually exclusive modes"
    );
    anyhow::ensure!(
        max_conns == 0 || listen.is_some(),
        "--max-conns only applies to the --listen server mode"
    );
    anyhow::ensure!(read_timeout >= 1, "--read-timeout must be >= 1");
    if let Some(addr) = &listen {
        anyhow::ensure!(
            id == "cdgrab",
            "--listen only applies to `exp cdgrab`"
        );
        return crate::ordering::transport::tcp::run_worker_server(
            addr,
            if max_conns > 0 { Some(max_conns) } else { None },
        );
    }
    if let Some(addr) = &register {
        anyhow::ensure!(
            id == "cdgrab",
            "--register only applies to `exp cdgrab`"
        );
        return crate::ordering::transport::tcp::run_registered_worker(
            addr,
            std::time::Duration::from_secs(read_timeout),
        );
    }
    if let Some(addr) = &service {
        anyhow::ensure!(
            id == "cdgrab",
            "--service only applies to `exp cdgrab`"
        );
        let mut cfg = if paper_scale {
            cdgrab::CdGrabConfig::default()
        } else {
            cdgrab::CdGrabConfig::small()
        };
        if epochs > 0 {
            cfg.epochs = epochs;
        }
        if n > 0 {
            cfg.n = n;
        }
        cfg.read_timeout_secs = read_timeout;
        return crate::service::client::run_job_against_daemon(
            addr, &cfg, &out,
        );
    }
    if connect.is_some() {
        anyhow::ensure!(
            id == "cdgrab",
            "--connect only applies to `exp cdgrab`"
        );
    }
    anyhow::ensure!(
        checkpoint_dir.is_none() || id == "cdgrab",
        "--checkpoint-dir only applies to `exp cdgrab`"
    );
    anyhow::ensure!(checkpoint_every >= 1, "--checkpoint-every must be >= 1");
    anyhow::ensure!(
        !resume || checkpoint_dir.is_some(),
        "--resume needs --checkpoint-dir (the run directory to resume \
         from)"
    );

    let ids: Vec<&str> = if id == "all" {
        vec!["fig1", "fig2", "fig3", "fig4", "table1", "statement1",
             "granularity", "cdgrab", "stream"]
    } else {
        vec![id.as_str()]
    };
    for id in ids {
        eprintln!("[exp] running {id} (scale={scale}) -> {}",
                  out.display());
        match id {
            "fig1" => {
                let mut cfg = if paper_scale {
                    fig1::Fig1Config::default()
                } else {
                    fig1::Fig1Config {
                        n: 4000,
                        ..fig1::Fig1Config::default()
                    }
                };
                if n > 0 {
                    cfg.n = n;
                }
                fig1::run(&cfg, &out)?;
            }
            "fig2" => {
                let mut cfg = if paper_scale {
                    fig2::Fig2Config::paper(&artifacts)
                } else {
                    fig2::Fig2Config::small(&artifacts)
                };
                if let Some(t) = &task_filter {
                    cfg.tasks = vec![Task::parse(t)?];
                }
                if epochs > 0 {
                    cfg.epochs = epochs;
                }
                if n > 0 {
                    cfg.n = n;
                }
                fig2::run(&cfg, &out)?;
            }
            "fig3" => {
                let mut cfg = fig3::Fig3Config::small(&artifacts);
                if paper_scale {
                    cfg.epochs = 30;
                    cfg.n = 4096;
                }
                if let Some(t) = &task_filter {
                    cfg.tasks = vec![Task::parse(t)?];
                }
                if epochs > 0 {
                    cfg.epochs = epochs;
                }
                if n > 0 {
                    cfg.n = n;
                }
                fig3::run(&cfg, &out)?;
            }
            "fig4" => {
                let cfg = if paper_scale {
                    fig4::Fig4Config::default()
                } else {
                    fig4::Fig4Config::small()
                };
                fig4::run(&cfg, &out)?;
            }
            "table1" => {
                let cfg = if paper_scale {
                    table1::Table1Config::default()
                } else {
                    table1::Table1Config::small()
                };
                table1::run(&cfg, &out)?;
            }
            "statement1" => {
                statement1::run(&statement1::Statement1Config::default(),
                                &out)?;
            }
            "granularity" => {
                let mut cfg = granularity::GranularityConfig::small(
                    &artifacts);
                if epochs > 0 {
                    cfg.epochs = epochs;
                }
                if n > 0 {
                    cfg.n = n;
                }
                granularity::run(&cfg, &out)?;
            }
            "cdgrab" => {
                let mut cfg = if paper_scale {
                    cdgrab::CdGrabConfig::default()
                } else {
                    cdgrab::CdGrabConfig::small()
                };
                if epochs > 0 {
                    cfg.epochs = epochs;
                }
                if n > 0 {
                    cfg.n = n;
                }
                cfg.connect = connect.clone();
                cfg.read_timeout_secs = read_timeout;
                cfg.checkpoint_dir = checkpoint_dir.clone();
                cfg.checkpoint_every = checkpoint_every;
                cfg.resume = resume;
                cdgrab::run(&cfg, &out)?;
            }
            "stream" => {
                let mut cfg = if paper_scale {
                    stream::StreamExpConfig::default()
                } else {
                    stream::StreamExpConfig::small()
                };
                if epochs > 0 {
                    cfg.windows = epochs;
                }
                if n > 0 {
                    cfg.n = n;
                }
                if let Some(r) = admit_rate {
                    cfg.admit_rate = r;
                }
                stream::run(&cfg, &out)?;
            }
            other => bail!(
                "unknown experiment {other:?} (fig1|fig2|fig3|fig4|\
                 table1|statement1|granularity|cdgrab|stream|all)"
            ),
        }
    }
    Ok(())
}
