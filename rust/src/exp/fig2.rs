//! Fig. 2 — training/validation curves on the four tasks under the five
//! orderings (RR, SO, FlipFlop, Greedy Ordering, GraB), at matched
//! hyperparameters (GraB reuses RR's, as in the paper).
//!
//! Emits one CSV with every (task, ordering, epoch) row plus a printed
//! summary of final losses, wall-clock and ordering-state memory — the
//! quantities behind both the curves and the paper's "<1% of greedy's
//! memory / OOM" observations.

use anyhow::Result;

use crate::config::{OrderingKind, Task, TrainConfig};
use crate::runtime::Runtime;
use crate::train::Trainer;
use crate::util::ser::{fmt_f, CsvWriter};

/// Parameters of the Fig. 2 task × ordering training sweep.
pub struct Fig2Config {
    /// Tasks to sweep.
    pub tasks: Vec<Task>,
    /// Ordering policies to sweep.
    pub orderings: Vec<OrderingKind>,
    /// Epochs per run.
    pub epochs: usize,
    /// Train set size.
    pub n: usize,
    /// Eval set size.
    pub n_eval: usize,
    /// RNG seed shared by every run.
    pub seed: u64,
    /// Compiled-artifact directory.
    pub artifacts_dir: String,
}

impl Fig2Config {
    /// CI-speed scale.
    pub fn small(artifacts_dir: &str) -> Fig2Config {
        Fig2Config {
            tasks: vec![Task::Mnist, Task::Cifar, Task::Wiki, Task::Glue],
            orderings: default_orderings(),
            epochs: 10,
            n: 1024,
            n_eval: 512,
            seed: 0,
            artifacts_dir: artifacts_dir.to_string(),
        }
    }

    /// Paper-matched scale.
    pub fn paper(artifacts_dir: &str) -> Fig2Config {
        Fig2Config {
            epochs: 30,
            n: 8192,
            n_eval: 2048,
            ..Fig2Config::small(artifacts_dir)
        }
    }
}

/// The paper's Section 6 ordering lineup.
pub fn default_orderings() -> Vec<OrderingKind> {
    vec![
        OrderingKind::RandomReshuffle,
        OrderingKind::ShuffleOnce,
        OrderingKind::FlipFlop,
        OrderingKind::GreedyOrdering,
        OrderingKind::GraB,
    ]
}

/// Per-run summary used by the printed table.
#[derive(Clone, Debug)]
pub struct RunSummary {
    /// Task name.
    pub task: &'static str,
    /// Ordering-policy name.
    pub ordering: &'static str,
    /// Final-epoch train loss.
    pub final_train_loss: f64,
    /// Final-epoch eval loss.
    pub final_eval_loss: f64,
    /// Final-epoch eval accuracy.
    pub final_eval_acc: f64,
    /// Total run wall-clock seconds.
    pub total_secs: f64,
    /// Seconds spent in the ordering policy.
    pub order_secs: f64,
    /// Ordering state bytes at the end.
    pub state_bytes: usize,
}

/// Run the sweep and write `fig2_training.csv` to `out_dir`.
pub fn run(cfg: &Fig2Config, out_dir: &std::path::Path) -> Result<()> {
    let rt = Runtime::open(&cfg.artifacts_dir)?;
    let mut csv = CsvWriter::create(
        &out_dir.join("fig2_curves.csv"),
        &[
            "task", "ordering", "epoch", "train_loss", "eval_loss",
            "eval_acc", "epoch_secs", "order_secs", "state_bytes",
        ],
    )?;
    let mut summaries = Vec::new();

    for &task in &cfg.tasks {
        for &ordering in &cfg.orderings {
            let mut tc = TrainConfig::for_task(task);
            tc.ordering = ordering;
            tc.epochs = cfg.epochs;
            tc.n_examples = cfg.n;
            tc.n_eval = cfg.n_eval;
            tc.seed = cfg.seed;
            tc.eval_every = 1;
            tc.artifacts_dir = cfg.artifacts_dir.clone();
            eprintln!("[fig2] {} / {}", task.name(), ordering.name());
            let mut trainer = Trainer::new(tc, &rt, None)?;
            let result = trainer.run()?;

            let mut total_secs = 0.0;
            let mut order_secs = 0.0;
            for m in &result.epochs {
                total_secs += m.epoch_secs;
                order_secs += m.order_secs;
                csv.row(&[
                    task.name().to_string(),
                    ordering.name().to_string(),
                    m.epoch.to_string(),
                    fmt_f(m.train_loss),
                    m.eval_loss.map(fmt_f).unwrap_or_default(),
                    m.eval_acc.map(fmt_f).unwrap_or_default(),
                    fmt_f(m.epoch_secs),
                    fmt_f(m.order_secs),
                    m.order_state_bytes.to_string(),
                ])?;
            }
            let last = result.epochs.last().expect("epochs");
            summaries.push(RunSummary {
                task: task.name(),
                ordering: ordering.name(),
                final_train_loss: last.train_loss,
                final_eval_loss: last.eval_loss.unwrap_or(f64::NAN),
                final_eval_acc: last.eval_acc.unwrap_or(f64::NAN),
                total_secs,
                order_secs,
                state_bytes: result.order_state_bytes,
            });
        }
    }
    csv.flush()?;
    print_summary(&summaries);
    Ok(())
}

/// Print the sweep's final-epoch summary table.
pub fn print_summary(rows: &[RunSummary]) {
    println!(
        "\nfig2 — final metrics (per task, lower loss / higher acc better):"
    );
    println!(
        "{:<7} {:<9} {:>11} {:>10} {:>9} {:>9} {:>10} {:>12}",
        "task", "ordering", "train_loss", "eval_loss", "eval_acc",
        "time(s)", "order(s)", "state_bytes"
    );
    for r in rows {
        println!(
            "{:<7} {:<9} {:>11.4} {:>10.4} {:>9.3} {:>9.2} {:>10.3} {:>12}",
            r.task,
            r.ordering,
            r.final_train_loss,
            r.final_eval_loss,
            r.final_eval_acc,
            r.total_secs,
            r.order_secs,
            r.state_bytes
        );
    }
    // The paper's headline: GraB <= RR on train loss, with ~O(d) state vs
    // greedy's O(nd).
    for task in ["mnist", "cifar", "wiki", "glue"] {
        let find = |ord: &str| {
            rows.iter()
                .find(|r| r.task == task && r.ordering == ord)
        };
        if let (Some(grab), Some(greedy)) = (find("grab"), find("greedy")) {
            if greedy.state_bytes > 0 {
                let ratio = grab.state_bytes as f64
                    / greedy.state_bytes as f64;
                println!(
                    "  {task}: GraB ordering state = {:.2}% of Greedy's \
                     ({} vs {} bytes)",
                    100.0 * ratio,
                    grab.state_bytes,
                    greedy.state_bytes
                );
            }
        }
    }
}
