//! Table 1 — measured compute/storage overhead of ordering policies.
//!
//! The paper's theory columns:
//!
//! | policy  | compute over RR | storage over RR |
//! |---------|-----------------|-----------------|
//! | RR      | N/A             | N/A             |
//! | Herding (greedy) | O(n²)  | O(nd)           |
//! | GraB    | O(n)            | O(d)            |
//!
//! This experiment *measures* both columns on synthetic gradient streams
//! across an n-sweep at fixed d, fits the scaling exponents, and prints the
//! resulting table. The convergence-rate columns of Table 1 are exercised
//! by fig2 (loss curves) and the herding-bound experiments (fig1/fig4).

use anyhow::Result;

use crate::ordering::{GraBOrder, GreedyOrder, OrderPolicy,
                      PairBalance, RandomReshuffle};
use crate::util::prop::gen;
use crate::util::rng::Rng;
use crate::util::ser::{fmt_f, CsvWriter};
use crate::util::stats::scaling_exponent;
use crate::util::timer::Stopwatch;

/// Parameters of the Table 1 overhead measurement.
pub struct Table1Config {
    /// Gradient dimension.
    pub d: usize,
    /// Dataset sizes to sweep.
    pub ns: Vec<usize>,
    /// RNG seed.
    pub seed: u64,
}

impl Default for Table1Config {
    fn default() -> Self {
        Table1Config {
            d: 7850, // the paper's MNIST logreg dimension
            ns: vec![256, 512, 1024, 2048],
            seed: 0,
        }
    }
}

impl Table1Config {
    /// CI-speed scale.
    pub fn small() -> Table1Config {
        Table1Config { d: 1024, ns: vec![128, 256, 512, 1024], seed: 0 }
    }
}

#[derive(Clone, Debug)]
/// One measured (policy, n) cell of Table 1.
pub struct Row {
    /// Ordering-policy name.
    pub policy: &'static str,
    /// Dataset size.
    pub n: usize,
    /// Seconds in observe + epoch_end for one epoch.
    pub order_secs: f64,
    /// Ordering state bytes.
    pub state_bytes: usize,
}

/// Microbatch width used when streaming gradients through a policy (the
/// executor's block size in real training).
const BLOCK: usize = 32;

/// Feed one epoch of synthetic gradients through a policy in contiguous
/// blocks (`ordering::stream_static_epoch`: gather happens outside the
/// timed section, as the loader stage does in training) and measure
/// ordering time (observe + epoch_end) and retained state.
fn measure(
    policy: &mut dyn OrderPolicy,
    vs: &[Vec<f32>],
) -> (f64, usize) {
    let secs = if policy.wants_grads() {
        let mut flat = Vec::new();
        // One measured epoch per policy instance, so the index is 0.
        crate::ordering::stream_static_epoch(
            policy, 0, vs, &mut flat, BLOCK,
        )
    } else {
        // Consistent with stream_static_epoch's stopwatch: epoch_order
        // (rr's shuffle) stays outside the timed section for every
        // policy; only observe + epoch_end are charged.
        let _ = policy.epoch_order(0);
        let sw = Stopwatch::start();
        policy.epoch_end();
        sw.secs()
    };
    (secs, policy.state_bytes())
}

/// Run the measurement and write `table1_overhead.csv` to `out_dir`.
pub fn run(cfg: &Table1Config, out_dir: &std::path::Path) -> Result<()> {
    let mut csv = CsvWriter::create(
        &out_dir.join("table1_overhead.csv"),
        &["policy", "n", "d", "order_secs", "state_bytes"],
    )?;
    let mut rows: Vec<Row> = Vec::new();
    for &n in &cfg.ns {
        let mut rng = Rng::new(cfg.seed ^ n as u64);
        let vs = gen::vec_set(&mut rng, n, cfg.d);
        for policy_name in ["rr", "greedy", "grab", "pair"] {
            let mut policy: Box<dyn OrderPolicy> = match policy_name {
                "rr" => Box::new(RandomReshuffle::new(n, cfg.seed)),
                "greedy" => Box::new(GreedyOrder::new(n, cfg.d)),
                "pair" => Box::new(PairBalance::new(n, cfg.d)),
                _ => Box::new(GraBOrder::new(
                    n,
                    cfg.d,
                    Box::new(crate::balance::DeterministicBalancer),
                )),
            };
            let (secs, bytes) = measure(policy.as_mut(), &vs);
            csv.row(&[
                policy_name.to_string(),
                n.to_string(),
                cfg.d.to_string(),
                fmt_f(secs),
                bytes.to_string(),
            ])?;
            rows.push(Row {
                policy: match policy_name {
                    "rr" => "rr",
                    "greedy" => "greedy",
                    "pair" => "pair",
                    _ => "grab",
                },
                n,
                order_secs: secs,
                state_bytes: bytes,
            });
        }
    }
    csv.flush()?;
    print_table(cfg, &rows);
    Ok(())
}

/// Print the measured rows in the paper's table layout.
pub fn print_table(cfg: &Table1Config, rows: &[Row]) {
    println!("\ntable1 — measured ordering overhead (d={}):", cfg.d);
    println!(
        "{:<8} {:>8} {:>14} {:>14}",
        "policy", "n", "order_time(s)", "state_bytes"
    );
    for r in rows {
        println!(
            "{:<8} {:>8} {:>14.5} {:>14}",
            r.policy, r.n, r.order_secs, r.state_bytes
        );
    }
    // Scaling exponents in n (compute) for greedy vs grab vs pair.
    for policy in ["greedy", "grab", "pair"] {
        let pts: Vec<&Row> =
            rows.iter().filter(|r| r.policy == policy).collect();
        if pts.len() >= 2 {
            let xs: Vec<f64> = pts.iter().map(|r| r.n as f64).collect();
            let ts: Vec<f64> =
                pts.iter().map(|r| r.order_secs.max(1e-9)).collect();
            let bs: Vec<f64> =
                pts.iter().map(|r| r.state_bytes as f64).collect();
            println!(
                "  {policy}: compute ~ n^{:.2} (theory: {}), \
                 storage ~ n^{:.2} (theory: {})",
                scaling_exponent(&xs, &ts),
                if policy == "greedy" { "n^2" } else { "n^1" },
                scaling_exponent(&xs, &bs),
                if policy == "greedy" { "n^1 (O(nd))" }
                else { "n^1 perms only (O(d) vectors)" },
            );
        }
    }
    // GraB d-vector state vs greedy at the largest n.
    if let (Some(grab), Some(greedy)) = (
        rows.iter().rfind(|r| r.policy == "grab"),
        rows.iter().rfind(|r| r.policy == "greedy"),
    ) {
        println!(
            "  at n={}: GraB state = {:.2}% of Greedy's",
            grab.n,
            100.0 * grab.state_bytes as f64 / greedy.state_bytes as f64
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_small_has_expected_scalings() {
        let dir = std::env::temp_dir().join("grab_table1_test");
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = Table1Config { d: 64, ns: vec![64, 128, 256], seed: 1 };
        run(&cfg, &dir).unwrap();
        let text = std::fs::read_to_string(
            dir.join("table1_overhead.csv")).unwrap();
        // Header + 4 policies x 3 dataset sizes.
        assert_eq!(text.lines().count(), 1 + 12);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn grab_state_much_smaller_than_greedy() {
        let mut rng = Rng::new(0);
        let (n, d) = (512, 1024);
        let vs = gen::vec_set(&mut rng, n, d);
        let mut greedy = GreedyOrder::new(n, d);
        let (_, greedy_bytes) = measure(&mut greedy, &vs);
        let mut grab = GraBOrder::new(
            n, d, Box::new(crate::balance::DeterministicBalancer));
        let (_, grab_bytes) = measure(&mut grab, &vs);
        // Paper: "less than 1% of the memory used by Greedy" for real
        // models; at this (n, d) the gradient storage dominates.
        assert!(
            (grab_bytes as f64) < 0.05 * greedy_bytes as f64,
            "grab {grab_bytes} vs greedy {greedy_bytes}"
        );
    }
}
