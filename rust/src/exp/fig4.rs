//! Fig. 4 — herding bound of Algorithm 5 (deterministic balancing) vs
//! Algorithm 6 (self-balancing walk) after 1 and after `passes` repeated
//! balance-reorder rounds, across dimensions — both ℓ∞ (the theory's norm)
//! and ℓ2 (where the paper notes naive balancing wins at high d).

use anyhow::Result;

use crate::balance::{Balancer, DeterministicBalancer, WalkBalancer};
use crate::herding::offline::herd;
use crate::util::rng::Rng;
use crate::util::ser::{fmt_f, CsvWriter};

/// Parameters of the Fig. 4 balancer-comparison experiment.
pub struct Fig4Config {
    /// Number of random vectors.
    pub n: usize,
    /// Dimensions to sweep.
    pub dims: Vec<usize>,
    /// Balance+reorder passes per dimension.
    pub passes: usize,
    /// RNG seed.
    pub seed: u64,
}

impl Default for Fig4Config {
    fn default() -> Self {
        Fig4Config { n: 10_000, dims: vec![16, 128, 1024], passes: 10,
                     seed: 0 }
    }
}

impl Fig4Config {
    /// CI-speed scale.
    pub fn small() -> Fig4Config {
        Fig4Config { n: 2000, dims: vec![16, 128, 512], passes: 10,
                     seed: 0 }
    }
}

/// Run the experiment and write `fig4_balancer_bounds.csv`.
pub fn run(cfg: &Fig4Config, out_dir: &std::path::Path) -> Result<()> {
    let mut csv = CsvWriter::create(
        &out_dir.join("fig4_balancer_bounds.csv"),
        &["algo", "d", "pass", "herding_inf", "herding_l2"],
    )?;
    println!(
        "\nfig4 — herding bound after repeated balance+reorder \
         (n={}):",
        cfg.n
    );
    println!(
        "{:<6} {:>6} {:>6} {:>14} {:>14}",
        "algo", "d", "pass", "herding_linf", "herding_l2"
    );
    for &d in &cfg.dims {
        let mut rng = Rng::new(cfg.seed ^ d as u64);
        // Paper's Fig. 4 setup: z_i sampled from [0,1]^d.
        let vs: Vec<Vec<f32>> = (0..cfg.n)
            .map(|_| (0..d).map(|_| rng.f32()).collect())
            .collect();
        for algo in ["alg5", "alg6"] {
            let mut balancer: Box<dyn Balancer> = match algo {
                "alg5" => Box::new(DeterministicBalancer),
                _ => Box::new(WalkBalancer::new(
                    // Tuned c (the paper's appendix notes Alg. 6 "requires
                    // tuning a hyperparameter c"): Theorem 4's
                    // 30·log(nd/δ) is a loose worst-case constant that
                    // makes the walk's signs near-coinflips; ln(nd) steers
                    // harder with rare failures. The walk's achieved bound
                    // floors at O(c), which is the paper's practical
                    // argument for preferring Alg. 5.
                    ((cfg.n * d) as f64).ln().max(2.0),
                    cfg.seed,
                )),
            };
            let (_, stats) = herd(balancer.as_mut(), &vs, cfg.passes);
            for s in &stats {
                csv.row(&[
                    algo.to_string(),
                    d.to_string(),
                    s.pass.to_string(),
                    fmt_f(s.herding_inf as f64),
                    fmt_f(s.herding_l2 as f64),
                ])?;
                if s.pass == 1 || s.pass == cfg.passes {
                    println!(
                        "{:<6} {:>6} {:>6} {:>14.4} {:>14.4}",
                        algo, d, s.pass, s.herding_inf, s.herding_l2
                    );
                }
            }
        }
    }
    csv.flush()?;
    println!(
        "(paper: both algorithms converge to similar bounds after ~10 \
         passes; alg5 wins on l2 at high d after 1 pass)"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_small_runs() {
        let dir = std::env::temp_dir().join("grab_fig4_test");
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = Fig4Config { n: 256, dims: vec![8, 32], passes: 3,
                               seed: 1 };
        run(&cfg, &dir).unwrap();
        let text = std::fs::read_to_string(
            dir.join("fig4_balancer_bounds.csv")).unwrap();
        // header + 2 algos * 2 dims * 3 passes
        assert_eq!(text.lines().count(), 1 + 12);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
