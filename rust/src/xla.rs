//! Stub for the `xla_extension` PJRT bindings.
//!
//! The real bindings (PJRT C API + compiled XLA) are a heavyweight native
//! dependency that is not part of this repository's vendored closure, so
//! this module provides the exact API surface [`crate::runtime`] consumes
//! and fails fast — [`PjRtClient::cpu`] returns an error, which surfaces
//! from `Runtime::open` with a clear message. Everything downstream of a
//! client (compile/execute/literal conversion) is therefore unreachable
//! in stub builds; the bodies exist only to typecheck.
//!
//! All runtime-dependent integration tests and experiments already skip
//! when `artifacts/manifest.json` is absent, so `cargo test` passes
//! offline: the ordering core, balancing, herding, config, and the
//! synthetic-stream experiments never touch this module.
//!
//! To use the real bindings: remove this file, drop `pub mod xla;` from
//! `src/lib.rs` and the `use crate::xla;` imports in `src/runtime/`, and
//! add the `xla` dependency to Cargo.toml.

use std::path::Path;

/// Error type mirroring the bindings' opaque error.
#[derive(Debug)]
pub struct Error(pub String);

fn unavailable<T>() -> Result<T, Error> {
    Err(Error(
        "PJRT unavailable: built against the xla stub (src/xla.rs); \
         install the xla_extension bindings to execute HLO artifacts"
            .to_string(),
    ))
}

/// Stub PJRT client — [`PjRtClient::cpu`] always fails.
pub struct PjRtClient;

impl PjRtClient {
    /// Open a CPU client — always errors in the stub build.
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable()
    }

    /// Platform name of the (never-constructed) stub client.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation — unreachable in stub builds.
    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }
}

/// Stub HLO module proto.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO-text artifact — always errors in the stub build.
    pub fn from_text_file(
        _path: impl AsRef<Path>,
    ) -> Result<HloModuleProto, Error> {
        unavailable()
    }
}

/// Stub XLA computation.
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a proto — a no-op in the stub build.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub loaded executable (never constructed in stub builds).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute — unreachable in stub builds.
    pub fn execute<L>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

/// Stub device buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Copy to host — unreachable in stub builds.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

/// Stub host literal.
pub struct Literal;

impl Literal {
    /// Build a rank-1 literal — a no-op in the stub build.
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    /// Reshape — unreachable in stub builds.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        unavailable()
    }

    /// Read back as a host vector — unreachable in stub builds.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable()
    }

    /// Unpack a 2-tuple — unreachable in stub builds.
    pub fn to_tuple2(&self) -> Result<(Literal, Literal), Error> {
        unavailable()
    }

    /// Unpack a 3-tuple — unreachable in stub builds.
    pub fn to_tuple3(
        &self,
    ) -> Result<(Literal, Literal, Literal), Error> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_client_fails_fast_with_clear_message() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(format!("{err:?}").contains("PJRT unavailable"));
    }
}
