//! Streaming pipeline — the threaded variant of the training loop.
//!
//! Three stages over bounded channels (std::sync::mpsc::sync_channel, so a
//! full queue blocks the producer = backpressure):
//!
//! ```text
//!   [loader thread] --HostBatch--> [grad thread] --GradOut--> [coordinator]
//!        gather                     PJRT execute               balance +
//!        (dataset)                  (own PJRT client)          optimizer
//! ```
//!
//! The grad stage owns its *own* PJRT client/executor (PJRT handles are not
//! Send; each thread builds its own from the artifact files). The
//! coordinator consumes results strictly in sequence order, so GraB's
//! sequential balance semantics are identical to the sync loop — only the
//! gather and the XLA execution overlap with balancing. Stall counters on
//! both queues quantify backpressure (reported in PipelineStats).
//!
//! The parameter vector is broadcast to the grad stage once per
//! *accumulation window* (params only change at optimizer steps), which is
//! what makes the overlap legal: microbatches within a window all see the
//! same params, matching the gradient-accumulation semantics of the sync
//! trainer.
//!
//! Composes with the async sharded coordinator (`--pipeline
//! --ordering cd-grab --async-shards`): the coordinator thread's
//! `observe_block` then only gathers + enqueues per-shard blocks, and
//! pair balancing runs on the shard workers concurrently with both the
//! grad stage and the optimizer. The `epoch_end` call below is the
//! single epoch-boundary barrier that drains those shard queues (and
//! re-raises a shard worker's panic); everything stays bit-identical to
//! the sync loop because shard streams are order-preserving SPSC queues
//! (see docs/determinism.md).

use std::sync::atomic::{AtomicU64, Ordering as AtomicOrdering};
use std::sync::mpsc::{SyncSender, TrySendError};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::TrainConfig;
use crate::data::loader::{HostBatch, Loader, Microbatch};
use crate::data::Dataset;
use crate::model::build_datasets;
use crate::optim::{GradAccumulator, MomentumSgd, Scheduler};
use crate::ordering::{build_policy, GradBlock, OrderPolicy};
use crate::runtime::Runtime;
use crate::train::{checkpoint, EpochMetrics, TrainResult};
use crate::util::timer::Stopwatch;

/// Work item sent to the grad stage.
struct GradJob {
    seq: usize,
    mb: Microbatch,
    host: HostBatch,
    /// Params snapshot for this job's accumulation window.
    params: Option<Arc<Vec<f32>>>,
}

/// Result returned by the grad stage.
struct GradOut {
    seq: usize,
    mb: Microbatch,
    losses: Vec<f32>,
    grads: Vec<f32>,
}

/// Queue/stall statistics for one pipelined run.
#[derive(Clone, Debug, Default)]
pub struct PipelineStats {
    /// Times the loader blocked on a full grad queue.
    pub loader_stalls: u64,
    /// Times the grad stage blocked pushing results.
    pub grad_stalls: u64,
    /// Microbatches processed.
    pub batches: u64,
}

/// Pipelined trainer: same semantics as [`crate::train::Trainer`] but with
/// gather and PJRT execution overlapped with balancing/optimizing.
pub struct PipelineTrainer {
    cfg: TrainConfig,
    artifacts_dir: String,
    /// Training dataset (ordering units).
    pub train_ds: Dataset,
    /// The example-ordering policy under test.
    pub policy: Box<dyn OrderPolicy>,
    opt: MomentumSgd,
    sched: Scheduler,
    /// Flattened model parameters (layout per the artifact manifest).
    pub params: Vec<f32>,
    dim: usize,
    batch: usize,
    /// Queue/stall counters accumulated across epochs.
    pub stats: PipelineStats,
    /// First epoch [`PipelineTrainer::run`] will execute: 0 for a
    /// fresh run, `ckpt.epoch + 1` after [`PipelineTrainer::restore`].
    start_epoch: usize,
}

impl PipelineTrainer {
    /// Build a pipelined trainer from config against an opened runtime.
    pub fn new(cfg: TrainConfig, rt: &Runtime) -> Result<PipelineTrainer> {
        let model_name = cfg.task.model_name();
        let entry = rt.manifest.model(model_name)?.clone();
        let params = rt.init_params(model_name)?;
        let (train_ds, _eval) = build_datasets(&cfg);
        let policy = build_policy(&cfg, train_ds.len(), entry.dim, None)?;
        let opt = MomentumSgd::new(entry.dim, cfg.momentum,
                                   cfg.weight_decay);
        let sched = Scheduler::constant(cfg.lr);
        Ok(PipelineTrainer {
            artifacts_dir: cfg.artifacts_dir.clone(),
            cfg,
            train_ds,
            policy,
            opt,
            sched,
            params,
            dim: entry.dim,
            batch: entry.batch,
            stats: PipelineStats::default(),
            start_epoch: 0,
        })
    }

    /// Open/create the configured run directory, applying `--resume`
    /// (fingerprint-gated restore of the newest snapshot). `None` when
    /// checkpointing is off. Mirrors the sync trainer's gate so
    /// determinism contract 8 covers both loops.
    fn prepare_run_dir(&mut self) -> Result<Option<checkpoint::RunDir>> {
        let Some(dir) = self.cfg.checkpoint_dir.clone() else {
            return Ok(None);
        };
        let dir = std::path::PathBuf::from(dir);
        let manifest = checkpoint::manifest_for(
            self.cfg.fingerprint(),
            &self.cfg.run_id(),
            self.cfg.ordering.name(),
            self.cfg.kernels.name(),
            self.cfg.checkpoint_every as u64,
        );
        if self.cfg.resume {
            let rd = checkpoint::RunDir::open(&dir)?;
            rd.check_fingerprint(manifest.fingerprint)?;
            if let Some(ckpt) = rd.load_latest()? {
                eprintln!(
                    "[grab] resuming {}-pipeline from epoch {} ({})",
                    self.cfg.run_id(),
                    ckpt.epoch,
                    rd.path().display()
                );
                self.restore(&ckpt)?;
            }
            Ok(Some(rd))
        } else {
            Ok(Some(checkpoint::RunDir::create(&dir, manifest)?))
        }
    }

    /// Snapshot the run for resumption. Must be called between epochs
    /// (after `run_epoch(epoch)` returned): the stage threads are
    /// joined there, so the coordinator-owned params/optimizer/policy
    /// state *is* the whole run state — the pipeline's epoch barrier
    /// makes its snapshot exactly as complete as the sync trainer's.
    pub fn snapshot(&mut self, epoch: usize) -> checkpoint::Checkpoint {
        let (lr, best, bad) = self.sched.state();
        checkpoint::Checkpoint {
            epoch: epoch as u64,
            params: self.params.clone(),
            velocity: self.opt.velocity().to_vec(),
            order: self
                .policy
                .epoch_order(epoch)
                .iter()
                .map(|&i| i as u64)
                .collect(),
            sched: Some((lr, best, bad as u64)),
            policy_state: self.policy.save_state(),
        }
    }

    /// Restore the full run state from a snapshot and arm
    /// [`PipelineTrainer::run`] to continue at `ckpt.epoch + 1`. Same
    /// typed resume gate as the sync trainer
    /// ([`checkpoint::restore_policy`]).
    pub fn restore(&mut self, ckpt: &checkpoint::Checkpoint)
        -> crate::Result<()> {
        anyhow::ensure!(ckpt.params.len() == self.params.len(),
                        "checkpoint dim mismatch");
        self.params.copy_from_slice(&ckpt.params);
        self.opt.set_velocity(&ckpt.velocity)?;
        if let Some((lr, best, bad)) = ckpt.sched {
            self.sched.restore_state(lr, best, bad as usize);
        }
        checkpoint::restore_policy(self.policy.as_mut(), ckpt)?;
        self.start_epoch = ckpt.epoch as usize + 1;
        Ok(())
    }

    /// Run all epochs through the pipeline (from the restored epoch
    /// after [`PipelineTrainer::restore`]), snapshotting into the run
    /// directory every `checkpoint_every` epochs when one is
    /// configured.
    pub fn run(&mut self) -> Result<TrainResult> {
        let run_dir = self.prepare_run_dir()?;
        let start = self.start_epoch.min(self.cfg.epochs);
        let mut epochs = Vec::with_capacity(self.cfg.epochs - start);
        for epoch in start..self.cfg.epochs {
            epochs.push(self.run_epoch(epoch)?);
            if let Some(rd) = &run_dir {
                let every = self.cfg.checkpoint_every.max(1);
                if (epoch + 1) % every == 0
                    || epoch + 1 == self.cfg.epochs
                {
                    let snap = self.snapshot(epoch);
                    rd.save_epoch(
                        &snap,
                        checkpoint::DEFAULT_KEEP_LAST,
                    )?;
                }
            }
        }
        let final_order = self.policy.epoch_order(self.cfg.epochs).to_vec();
        Ok(TrainResult {
            run_id: format!("{}-pipeline", self.cfg.run_id()),
            epochs,
            final_order,
            order_state_bytes: self.policy.state_bytes(),
            transport: self.policy.transport_stats(),
            topology: self.policy.topology_log().map(|l| l.to_vec()),
        })
    }

    /// One pipelined epoch. Public for the crash-replay test layer
    /// (tests/checkpoint.rs kills a run between epochs), mirroring
    /// [`crate::train::Trainer::run_epoch`].
    pub fn run_epoch(&mut self, epoch: usize) -> Result<EpochMetrics> {
        let sw_epoch = Stopwatch::start();
        let b = self.batch;
        let d = self.dim;
        let n = self.train_ds.len();
        let lr = self.sched.lr();
        let wants_grads = self.policy.wants_grads();
        let window = b * self.cfg.accum_steps;

        let mbs: Vec<Microbatch> =
            Loader::new(self.policy.epoch_order(epoch), b).collect();
        let total = mbs.len();

        // Channel capacities: small and bounded => real backpressure.
        const QCAP: usize = 4;
        let workers = self.cfg.workers.max(1);
        let mut job_txs = Vec::with_capacity(workers);
        let mut job_rxs = Vec::with_capacity(workers);
        for _ in 0..workers {
            let (tx, rx) = std::sync::mpsc::sync_channel::<GradJob>(QCAP);
            job_txs.push(tx);
            job_rxs.push(rx);
        }
        let (out_tx, out_rx) =
            std::sync::mpsc::sync_channel::<GradOut>(QCAP * workers);
        let loader_stalls = Arc::new(AtomicU64::new(0));
        let grad_stalls = Arc::new(AtomicU64::new(0));

        // ---- loader stage -------------------------------------------------
        // Microbatches shard round-robin across grad workers (see
        // data::shard::ShardPlan for the ownership law tested there).
        let ds = self.train_ds.clone();
        let params0 = Arc::new(self.params.clone());
        let ls = Arc::clone(&loader_stalls);
        let loader = std::thread::spawn(move || {
            let mut first_seen = vec![true; job_txs.len()];
            for (seq, mb) in mbs.into_iter().enumerate() {
                let w = seq % job_txs.len();
                let mut host = HostBatch::default();
                host.fill(&ds, &mb);
                let job = GradJob {
                    seq,
                    mb,
                    host,
                    // Every worker's FIRST job carries the initial params.
                    params: if std::mem::take(&mut first_seen[w]) {
                        Some(Arc::clone(&params0))
                    } else {
                        None
                    },
                };
                send_counting(&job_txs[w], job, &ls);
            }
        });

        // ---- grad stage ---------------------------------------------------
        // Each worker owns its own PJRT client (PJRT handles are not Send);
        // params updates arrive on a per-worker channel so every worker can
        // catch up to the coordinator's optimizer steps.
        let mut pchan_txs = Vec::with_capacity(workers);
        let mut grad_threads = Vec::with_capacity(workers);
        let accum_steps = self.cfg.accum_steps;
        for job_rx in job_rxs {
            let (pchan_tx, pchan_rx) =
                std::sync::mpsc::channel::<Arc<Vec<f32>>>();
            pchan_txs.push(pchan_tx);
            let artifacts = self.artifacts_dir.clone();
            let model_name = self.cfg.task.model_name().to_string();
            let gs = Arc::clone(&grad_stalls);
            let out_tx = out_tx.clone();
            grad_threads.push(std::thread::spawn(move || -> Result<()> {
                let rt = Runtime::open(&artifacts)
                    .context("grad stage runtime")?;
                let exec = rt.grad_executor(&model_name)?;
                let mut params: Option<Arc<Vec<f32>>> = None;
                let mut last_window = 0usize;
                let mut losses = Vec::new();
                let mut grads = Vec::new();
                while let Ok(job) = job_rx.recv() {
                    if let Some(p) = job.params {
                        params = Some(p);
                    }
                    // Optimizer steps land exactly at accumulation-window
                    // boundaries (one window = accum_steps microbatches):
                    // entering window W requires the post-step params of
                    // window W-1. The coordinator broadcasts one snapshot
                    // per step to EVERY worker, so catching up from window
                    // a to b means receiving exactly b-a messages. This is
                    // what keeps the pipelined run bit-identical to the
                    // sync loop while overlapping execute with balancing.
                    let window = job.seq / accum_steps;
                    while last_window < window {
                        let p = pchan_rx.recv().map_err(|_| {
                            anyhow::anyhow!("coordinator gone")
                        })?;
                        params = Some(p);
                        last_window += 1;
                    }
                    let p = params.as_ref().expect("params snapshot");
                    exec.run(
                        p, &job.host.x_f32, &job.host.x_i32, &job.host.y,
                        &mut losses, &mut grads,
                    )?;
                    let out = GradOut {
                        seq: job.seq,
                        mb: job.mb,
                        losses: losses.clone(),
                        grads: grads.clone(),
                    };
                    send_counting(&out_tx, out, &gs);
                }
                Ok(())
            }));
        }
        drop(out_tx);

        // ---- coordinator (this thread): balance + optimize ---------------
        let mut accum = GradAccumulator::new(d, window);
        let mut loss_sum = 0.0f64;
        let mut order_secs = 0.0f64;
        let mut steps = 0usize;
        let mut next_seq = 0usize;
        // Reassembly buffer: results may arrive out of order across
        // workers; GraB's balance is sequential, so consume strictly by
        // sequence number.
        let mut pending: std::collections::BTreeMap<usize, GradOut> =
            std::collections::BTreeMap::new();
        while next_seq < total {
            let out = if let Some(o) = pending.remove(&next_seq) {
                o
            } else {
                let o = out_rx
                    .recv()
                    .map_err(|_| anyhow::anyhow!("grad stage died"))?;
                if o.seq != next_seq {
                    pending.insert(o.seq, o);
                    continue;
                }
                o
            };
            next_seq += 1;
            // Same block semantics as the sync trainer: the valid prefix
            // of the worker's gradient buffer is one zero-copy GradBlock,
            // so both paths produce byte-identical GraB orders.
            if wants_grads && out.mb.valid > 0 {
                let sw = Stopwatch::start();
                self.policy.observe_block(
                    out.mb.offset..out.mb.offset + out.mb.valid,
                    &GradBlock::new(&out.grads[..out.mb.valid * d], d),
                );
                order_secs += sw.secs();
            }
            for i in 0..out.mb.valid {
                let g = &out.grads[i * d..(i + 1) * d];
                loss_sum += out.losses[i] as f64;
                if let Some(mean) = accum.push(g) {
                    let mut mean = mean.to_vec();
                    crate::optim::clip_global_norm(
                        &mut mean, self.cfg.clip_norm);
                    self.opt.step(&mut self.params, &mean, lr);
                    accum.clear();
                    steps += 1;
                    // Broadcast fresh params to every worker (they block
                    // on this at each window boundary).
                    let snap = Arc::new(self.params.clone());
                    for tx in &pchan_txs {
                        let _ = tx.send(Arc::clone(&snap));
                    }
                }
            }
        }
        if let Some(mean) = accum.flush() {
            let mut mean = mean.to_vec();
            crate::optim::clip_global_norm(&mut mean, self.cfg.clip_norm);
            self.opt.step(&mut self.params, &mean, lr);
            steps += 1;
        }
        // Epoch-boundary barrier: drains async shard queues (if any)
        // before the stage threads are reaped, so a worker panic
        // surfaces here rather than poisoning the next epoch.
        let sw = Stopwatch::start();
        self.policy.epoch_end();
        order_secs += sw.secs();

        loader.join().expect("loader thread");
        for t in grad_threads {
            t.join().expect("grad thread")?;
        }

        self.stats.loader_stalls +=
            loader_stalls.load(AtomicOrdering::Relaxed);
        self.stats.grad_stalls +=
            grad_stalls.load(AtomicOrdering::Relaxed);
        self.stats.batches += total as u64;

        let train_loss = loss_sum / n as f64;
        self.sched.epoch_feedback(train_loss);
        Ok(EpochMetrics {
            epoch,
            train_loss,
            eval_loss: None,
            eval_acc: None,
            lr,
            optimizer_steps: steps,
            grad_secs: 0.0, // folded into epoch_secs (separate thread)
            order_secs,
            epoch_secs: sw_epoch.secs(),
            order_state_bytes: self.policy.state_bytes(),
        })
    }
}

/// send with stall counting: try_send first, count a stall if the queue is
/// full, then block.
fn send_counting<T>(tx: &SyncSender<T>, value: T, stalls: &AtomicU64) {
    match tx.try_send(value) {
        Ok(()) => {}
        Err(TrySendError::Full(v)) => {
            stalls.fetch_add(1, AtomicOrdering::Relaxed);
            let _ = tx.send(v);
        }
        Err(TrySendError::Disconnected(_)) => {}
    }
}

/// Drain helper for tests: consume a receiver into a vec.
#[cfg(test)]
fn drain<T>(rx: std::sync::mpsc::Receiver<T>) -> Vec<T> {
    rx.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn send_counting_counts_full_queue() {
        let (tx, rx) = std::sync::mpsc::sync_channel::<u32>(1);
        let stalls = Arc::new(AtomicU64::new(0));
        let s2 = Arc::clone(&stalls);
        let h = std::thread::spawn(move || {
            send_counting(&tx, 1, &s2);
            send_counting(&tx, 2, &s2); // queue full -> stall + block
        });
        std::thread::sleep(std::time::Duration::from_millis(30));
        let got = drain(rx);
        h.join().unwrap();
        assert_eq!(got, vec![1, 2]);
        assert_eq!(stalls.load(AtomicOrdering::Relaxed), 1);
    }
}
