//! Artifact manifest: the typed view of `artifacts/manifest.json`, the
//! contract between the L2 compile path (aot.py) and the L3 coordinator.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::ser::Json;

/// One named parameter block in the flat layout.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    /// Parameter name (as exported by the model builder).
    pub name: String,
    /// Logical tensor shape.
    pub shape: Vec<usize>,
    /// Start offset in the flat parameter vector.
    pub offset: usize,
    /// Element count (product of `shape`).
    pub size: usize,
}

/// Input dtype of the feature tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    /// 32-bit floats (dense features).
    F32,
    /// 32-bit ints (token ids).
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        Ok(match s {
            "f32" => Dtype::F32,
            "i32" => Dtype::I32,
            _ => bail!("unknown dtype {s:?}"),
        })
    }
}

/// Model entry: shapes/dtypes of the grad and eval artifacts.
#[derive(Clone, Debug)]
pub struct ModelEntry {
    /// Model family name (logreg, lenet, lstm, transformer).
    pub name: String,
    /// Flat parameter dimension d.
    pub dim: usize,
    /// Grad-artifact microbatch size B.
    pub batch: usize,
    /// Eval-artifact batch size E.
    pub eval_batch: usize,
    /// Per-example feature shape (flattened product below).
    pub x_shape: Vec<usize>,
    /// Feature dtype.
    pub x_dtype: Dtype,
    /// Per-example label shape ([] = scalar).
    pub y_shape: Vec<usize>,
    /// Output class count (0 for pure LM heads).
    pub n_classes: usize,
    /// Token vocabulary size (0 for dense-feature models).
    pub vocab: usize,
    /// Grad artifact file name (HLO text).
    pub grad_hlo: String,
    /// Eval artifact file name (HLO text).
    pub eval_hlo: String,
    /// Initial-parameter file name (little-endian f32).
    pub init_params: String,
    /// Flat layout of the parameter vector.
    pub param_layout: Vec<ParamSpec>,
}

impl ModelEntry {
    /// Flattened per-example feature width.
    pub fn x_width(&self) -> usize {
        self.x_shape.iter().product::<usize>().max(1)
    }

    /// Flattened per-example label width (1 for scalar labels).
    pub fn y_width(&self) -> usize {
        self.y_shape.iter().product::<usize>().max(1)
    }
}

/// Balance-kernel entry.
#[derive(Clone, Debug)]
pub struct BalanceEntry {
    /// Vector dimension the kernel was lowered for.
    pub dim: usize,
    /// Kernel artifact file name (HLO text).
    pub hlo: String,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Model artifacts.
    pub models: Vec<ModelEntry>,
    /// Balance-kernel artifacts.
    pub balance: Vec<BalanceEntry>,
    /// Fused momentum-SGD optimizer artifacts (optional — older manifests
    /// predate them).
    pub sgd: Vec<BalanceEntry>,
}

impl Manifest {
    /// Read + parse `manifest.json`.
    pub fn load(path: &Path) -> Result<Manifest> {
        let json = Json::from_file(path)?;
        Manifest::from_json(&json)
            .with_context(|| format!("parsing {}", path.display()))
    }

    /// Parse a manifest from its JSON value (format 1 only).
    pub fn from_json(json: &Json) -> Result<Manifest> {
        let format = json.get("format")?.as_usize()?;
        if format != 1 {
            bail!("unsupported manifest format {format}");
        }
        let mut models = Vec::new();
        for m in json.get("models")?.as_arr()? {
            models.push(parse_model(m)?);
        }
        let mut balance = Vec::new();
        for b in json.get("balance")?.as_arr()? {
            balance.push(BalanceEntry {
                dim: b.get("dim")?.as_usize()?,
                hlo: b.get("hlo")?.as_str()?.to_string(),
            });
        }
        let mut sgd = Vec::new();
        if let Ok(arr) = json.get("sgd") {
            for b in arr.as_arr()? {
                sgd.push(BalanceEntry {
                    dim: b.get("dim")?.as_usize()?,
                    hlo: b.get("hlo")?.as_str()?.to_string(),
                });
            }
        }
        Ok(Manifest { models, balance, sgd })
    }

    /// Look up a model entry by name.
    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .with_context(|| {
                format!(
                    "model {name:?} not in manifest (have: {})",
                    self.models
                        .iter()
                        .map(|m| m.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })
    }
}

fn parse_model(m: &Json) -> Result<ModelEntry> {
    let usize_arr = |key: &str| -> Result<Vec<usize>> {
        m.get(key)?
            .as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect()
    };
    let mut param_layout = Vec::new();
    for p in m.get("param_layout")?.as_arr()? {
        param_layout.push(ParamSpec {
            name: p.get("name")?.as_str()?.to_string(),
            shape: p
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|v| v.as_usize())
                .collect::<Result<Vec<_>>>()?,
            offset: p.get("offset")?.as_usize()?,
            size: p.get("size")?.as_usize()?,
        });
    }
    let entry = ModelEntry {
        name: m.get("name")?.as_str()?.to_string(),
        dim: m.get("dim")?.as_usize()?,
        batch: m.get("batch")?.as_usize()?,
        eval_batch: m.get("eval_batch")?.as_usize()?,
        x_shape: usize_arr("x_shape")?,
        x_dtype: Dtype::parse(m.get("x_dtype")?.as_str()?)?,
        y_shape: usize_arr("y_shape")?,
        n_classes: m.get("n_classes")?.as_usize()?,
        vocab: m.get("vocab")?.as_usize()?,
        grad_hlo: m.get("grad_hlo")?.as_str()?.to_string(),
        eval_hlo: m.get("eval_hlo")?.as_str()?.to_string(),
        init_params: m.get("init_params")?.as_str()?.to_string(),
        param_layout,
    };
    // Layout consistency: offsets contiguous, sizes sum to dim.
    let mut off = 0usize;
    for p in &entry.param_layout {
        if p.offset != off {
            bail!("param {} offset {} != expected {off}", p.name, p.offset);
        }
        let numel: usize = p.shape.iter().product::<usize>().max(1);
        if numel != p.size {
            bail!("param {} shape/size mismatch", p.name);
        }
        off += p.size;
    }
    if off != entry.dim {
        bail!("param layout sums to {off}, dim is {}", entry.dim);
    }
    Ok(entry)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Json {
        Json::parse(
            r#"{
  "format": 1,
  "models": [{
    "name": "logreg", "dim": 7850, "batch": 64, "eval_batch": 256,
    "x_shape": [784], "x_dtype": "f32", "y_shape": [], "y_dtype": "i32",
    "n_classes": 10, "vocab": 0,
    "grad_hlo": "logreg_grad.hlo.txt", "eval_hlo": "logreg_eval.hlo.txt",
    "init_params": "logreg_init.f32",
    "param_layout": [
      {"name": "w", "shape": [784, 10], "offset": 0, "size": 7840},
      {"name": "b", "shape": [10], "offset": 7840, "size": 10}
    ]
  }],
  "balance": [{"dim": 1024, "hlo": "balance_1024.hlo.txt"}]
}"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_sample() {
        let man = Manifest::from_json(&sample()).unwrap();
        assert_eq!(man.models.len(), 1);
        let m = man.model("logreg").unwrap();
        assert_eq!(m.dim, 7850);
        assert_eq!(m.x_width(), 784);
        assert_eq!(m.y_width(), 1);
        assert_eq!(m.x_dtype, Dtype::F32);
        assert_eq!(man.balance[0].dim, 1024);
        assert!(man.model("nope").is_err());
    }

    #[test]
    fn rejects_bad_layout() {
        let mut text = sample().to_string();
        text = text.replace("\"offset\":7840", "\"offset\":7000");
        let json = Json::parse(&text).unwrap();
        assert!(Manifest::from_json(&json).is_err());
    }

    #[test]
    fn rejects_wrong_format() {
        let text = sample().to_string().replace(
            "\"format\":1", "\"format\":99");
        let json = Json::parse(&text).unwrap();
        assert!(Manifest::from_json(&json).is_err());
    }

    #[test]
    fn parses_real_manifest_if_built() {
        let path = std::path::Path::new("artifacts/manifest.json");
        if path.exists() {
            let man = Manifest::load(path).unwrap();
            assert!(man.model("logreg").is_ok());
            assert!(man.model("transformer").is_ok());
            assert_eq!(man.balance.len(), 2);
        }
    }
}
