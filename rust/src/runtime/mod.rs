//! PJRT runtime — loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the rust request path.
//!
//! Flow (see /opt/xla-example/load_hlo for the reference wiring):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`.
//!
//! HLO **text** is the interchange format: jax ≥ 0.5 serializes protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids.

mod artifact;
mod executor;

pub use artifact::{BalanceEntry, Manifest, ModelEntry, ParamSpec};
pub use executor::{BalanceExecutor, EvalExecutor, GradExecutor, SgdExecutor};

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::xla;

/// Shared PJRT client + artifact directory. Compiling an HLO module is
/// expensive; executables are cached per artifact file by the executors.
pub struct Runtime {
    client: Arc<xla::PjRtClient>,
    dir: PathBuf,
    /// The parsed artifact manifest.
    pub manifest: Manifest,
}

impl Runtime {
    /// Open the artifact directory (reads `manifest.json`, starts the CPU
    /// PJRT client).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| {
                format!(
                    "loading manifest from {} — run `make artifacts` first",
                    dir.display()
                )
            })?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Runtime { client: Arc::new(client), dir, manifest })
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile one HLO-text artifact into a loaded executable.
    pub fn compile(&self, file: &str) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| {
                anyhow::anyhow!("parsing {}: {e:?}", path.display())
            })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {file}: {e:?}"))
    }

    /// Grad executor for a model (per-example losses + gradients).
    pub fn grad_executor(&self, model: &str) -> Result<GradExecutor> {
        let entry = self.manifest.model(model)?.clone();
        let exe = self.compile(&entry.grad_hlo)?;
        Ok(GradExecutor::new(entry, exe))
    }

    /// Eval executor for a model (summed loss + correct count).
    pub fn eval_executor(&self, model: &str) -> Result<EvalExecutor> {
        let entry = self.manifest.model(model)?.clone();
        let exe = self.compile(&entry.eval_hlo)?;
        Ok(EvalExecutor::new(entry, exe))
    }

    /// Balance-step executor (the L1 Pallas kernel artifact) for dim `d`.
    pub fn balance_executor(&self, d: usize) -> Result<BalanceExecutor> {
        let entry = self
            .manifest
            .balance
            .iter()
            .find(|b| b.dim == d)
            .with_context(|| format!("no balance artifact for d={d}"))?
            .clone();
        let exe = self.compile(&entry.hlo)?;
        Ok(BalanceExecutor::new(entry, exe))
    }

    /// Fused momentum-SGD optimizer executor (the L1 Pallas sgd kernel).
    pub fn sgd_executor(&self, d: usize) -> Result<SgdExecutor> {
        let entry = self
            .manifest
            .sgd
            .iter()
            .find(|b| b.dim == d)
            .with_context(|| {
                format!("no sgd artifact for d={d} (re-run `make artifacts`)")
            })?
            .clone();
        let exe = self.compile(&entry.hlo)?;
        Ok(SgdExecutor::new(entry, exe))
    }

    /// Initial parameters for a model (little-endian f32 file from aot.py).
    pub fn init_params(&self, model: &str) -> Result<Vec<f32>> {
        let entry = self.manifest.model(model)?;
        let path = self.dir.join(&entry.init_params);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        anyhow::ensure!(
            bytes.len() == entry.dim * 4,
            "init file {} has {} bytes, want {}",
            path.display(),
            bytes.len(),
            entry.dim * 4
        );
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}
