//! Typed executors over the compiled HLO artifacts.
//!
//! Each executor owns one `PjRtLoadedExecutable` and knows the artifact's
//! input/output shapes from the manifest, so the trainer deals only in
//! plain slices. All artifacts are lowered with `return_tuple=True`, so
//! outputs unwrap with `to_tupleN`.

use anyhow::{ensure, Result};

use super::artifact::{BalanceEntry, Dtype, ModelEntry};
use crate::xla;

fn xerr(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("xla: {e:?}")
}

/// Per-example gradient executor:
/// `(params[d], X[B, xw], Y[B, yw]) -> (losses[B], grads[B, d])`.
pub struct GradExecutor {
    /// The manifest entry this executor was compiled from.
    pub entry: ModelEntry,
    exe: xla::PjRtLoadedExecutable,
}

impl GradExecutor {
    /// Wrap a compiled grad artifact.
    pub fn new(entry: ModelEntry, exe: xla::PjRtLoadedExecutable) -> Self {
        GradExecutor { entry, exe }
    }

    /// Microbatch size B baked into the artifact.
    pub fn batch(&self) -> usize {
        self.entry.batch
    }

    /// Flat parameter dimension d.
    pub fn dim(&self) -> usize {
        self.entry.dim
    }

    /// Run one microbatch. Exactly one of `x_f32` / `x_i32` must be
    /// non-empty, matching the artifact's feature dtype. Outputs are
    /// written into `losses` (B) and `grads` (B*d), reused across calls.
    pub fn run(
        &self,
        params: &[f32],
        x_f32: &[f32],
        x_i32: &[i32],
        y: &[i32],
        losses: &mut Vec<f32>,
        grads: &mut Vec<f32>,
    ) -> Result<()> {
        let b = self.entry.batch;
        let d = self.entry.dim;
        let xw = self.entry.x_width();
        let yw = self.entry.y_width();
        ensure!(params.len() == d, "params len {} != d {d}", params.len());
        ensure!(y.len() == b * yw, "y len {} != {}", y.len(), b * yw);

        let p_lit = xla::Literal::vec1(params);
        let x_lit = match self.entry.x_dtype {
            Dtype::F32 => {
                ensure!(x_f32.len() == b * xw, "x len {}", x_f32.len());
                xla::Literal::vec1(x_f32)
                    .reshape(&[b as i64, xw as i64])
                    .map_err(xerr)?
            }
            Dtype::I32 => {
                ensure!(x_i32.len() == b * xw, "x len {}", x_i32.len());
                xla::Literal::vec1(x_i32)
                    .reshape(&[b as i64, xw as i64])
                    .map_err(xerr)?
            }
        };
        let y_lit = if yw == 1 {
            xla::Literal::vec1(y)
        } else {
            xla::Literal::vec1(y)
                .reshape(&[b as i64, yw as i64])
                .map_err(xerr)?
        };

        let result = self
            .exe
            .execute::<xla::Literal>(&[p_lit, x_lit, y_lit])
            .map_err(xerr)?;
        let tuple =
            result[0][0].to_literal_sync().map_err(xerr)?;
        let (l_lit, g_lit) = tuple.to_tuple2().map_err(xerr)?;
        *losses = l_lit.to_vec::<f32>().map_err(xerr)?;
        *grads = g_lit.to_vec::<f32>().map_err(xerr)?;
        ensure!(losses.len() == b, "losses len {}", losses.len());
        ensure!(grads.len() == b * d, "grads len {}", grads.len());
        Ok(())
    }
}

/// Evaluation executor:
/// `(params[d], X[E, xw], Y[E, yw]) -> (loss_sum, correct)`.
pub struct EvalExecutor {
    /// The manifest entry this executor was compiled from.
    pub entry: ModelEntry,
    exe: xla::PjRtLoadedExecutable,
}

impl EvalExecutor {
    /// Wrap a compiled eval artifact.
    pub fn new(entry: ModelEntry, exe: xla::PjRtLoadedExecutable) -> Self {
        EvalExecutor { entry, exe }
    }

    /// Eval batch size E baked into the artifact.
    pub fn batch(&self) -> usize {
        self.entry.eval_batch
    }

    /// Returns (summed loss, correct count) over one eval batch.
    pub fn run(
        &self,
        params: &[f32],
        x_f32: &[f32],
        x_i32: &[i32],
        y: &[i32],
    ) -> Result<(f32, f32)> {
        let e = self.entry.eval_batch;
        let xw = self.entry.x_width();
        let yw = self.entry.y_width();
        ensure!(params.len() == self.entry.dim);
        let p_lit = xla::Literal::vec1(params);
        let x_lit = match self.entry.x_dtype {
            Dtype::F32 => {
                ensure!(x_f32.len() == e * xw);
                xla::Literal::vec1(x_f32)
                    .reshape(&[e as i64, xw as i64])
                    .map_err(xerr)?
            }
            Dtype::I32 => {
                ensure!(x_i32.len() == e * xw);
                xla::Literal::vec1(x_i32)
                    .reshape(&[e as i64, xw as i64])
                    .map_err(xerr)?
            }
        };
        let y_lit = if yw == 1 {
            xla::Literal::vec1(y)
        } else {
            xla::Literal::vec1(y)
                .reshape(&[e as i64, yw as i64])
                .map_err(xerr)?
        };
        let result = self
            .exe
            .execute::<xla::Literal>(&[p_lit, x_lit, y_lit])
            .map_err(xerr)?;
        let tuple = result[0][0].to_literal_sync().map_err(xerr)?;
        let (l_lit, c_lit) = tuple.to_tuple2().map_err(xerr)?;
        let loss = l_lit.to_vec::<f32>().map_err(xerr)?[0];
        let correct = c_lit.to_vec::<f32>().map_err(xerr)?[0];
        Ok((loss, correct))
    }
}

/// GraB balance-step executor (the Pallas L1 kernel artifact):
/// `(s[d], m[d], g[d]) -> (eps, s_new[d], c[d])`.
pub struct BalanceExecutor {
    /// The manifest entry this executor was compiled from.
    pub entry: BalanceEntry,
    exe: xla::PjRtLoadedExecutable,
}

impl BalanceExecutor {
    /// Wrap a compiled balance-kernel artifact.
    pub fn new(entry: BalanceEntry, exe: xla::PjRtLoadedExecutable) -> Self {
        BalanceExecutor { entry, exe }
    }

    /// Vector dimension the kernel was lowered for.
    pub fn dim(&self) -> usize {
        self.entry.dim
    }

    /// One fused balance step; returns eps and overwrites `s` in place.
    pub fn step(&self, s: &mut Vec<f32>, m: &[f32], g: &[f32])
        -> Result<f32> {
        let d = self.entry.dim;
        ensure!(s.len() == d && m.len() == d && g.len() == d);
        let s_lit = xla::Literal::vec1(s.as_slice());
        let m_lit = xla::Literal::vec1(m);
        let g_lit = xla::Literal::vec1(g);
        let result = self
            .exe
            .execute::<xla::Literal>(&[s_lit, m_lit, g_lit])
            .map_err(xerr)?;
        let tuple = result[0][0].to_literal_sync().map_err(xerr)?;
        let (eps_lit, s_new, _c) = tuple.to_tuple3().map_err(xerr)?;
        let eps = eps_lit.to_vec::<f32>().map_err(xerr)?[0];
        *s = s_new.to_vec::<f32>().map_err(xerr)?;
        Ok(eps)
    }
}

/// Fused momentum-SGD optimizer executor (the L1 Pallas kernel artifact):
/// `(p[d], v[d], g[d], hyper[3]=(lr,mu,wd)) -> (p', v')`.
pub struct SgdExecutor {
    /// The manifest entry this executor was compiled from.
    pub entry: BalanceEntry,
    exe: xla::PjRtLoadedExecutable,
}

impl SgdExecutor {
    /// Wrap a compiled fused-SGD kernel artifact.
    pub fn new(entry: BalanceEntry, exe: xla::PjRtLoadedExecutable) -> Self {
        SgdExecutor { entry, exe }
    }

    /// Vector dimension the kernel was lowered for.
    pub fn dim(&self) -> usize {
        self.entry.dim
    }

    /// One fused optimizer step; overwrites `p` and `v` in place.
    pub fn step(
        &self,
        p: &mut Vec<f32>,
        v: &mut Vec<f32>,
        g: &[f32],
        lr: f32,
        momentum: f32,
        weight_decay: f32,
    ) -> Result<()> {
        let d = self.entry.dim;
        ensure!(p.len() == d && v.len() == d && g.len() == d);
        let hyper = [lr, momentum, weight_decay];
        let result = self
            .exe
            .execute::<xla::Literal>(&[
                xla::Literal::vec1(p.as_slice()),
                xla::Literal::vec1(v.as_slice()),
                xla::Literal::vec1(g),
                xla::Literal::vec1(&hyper),
            ])
            .map_err(xerr)?;
        let tuple = result[0][0].to_literal_sync().map_err(xerr)?;
        let (p_new, v_new) = tuple.to_tuple2().map_err(xerr)?;
        *p = p_new.to_vec::<f32>().map_err(xerr)?;
        *v = v_new.to_vec::<f32>().map_err(xerr)?;
        Ok(())
    }
}
