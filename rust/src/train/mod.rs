//! Training engine: wires dataset → loader → PJRT grad executor → ordering
//! policy → optimizer for a configured run, with per-epoch metrics.
//!
//! One *ordering unit* = one example (the per-example gradients come from
//! the vmap'd L2 artifact). The optimizer steps on the mean of
//! `B * accum_steps` unit gradients (the paper's gradient-accumulation
//! recipe), while the ordering policy observes every unit gradient
//! individually — exactly the granularity GraB needs.
//!
//! Epoch boundary contract: the trainer calls
//! [`OrderPolicy::epoch_end`] exactly once per epoch, after observing
//! all `n` units. For the async sharded coordinator
//! (`--ordering cd-grab --async-shards`) that call *is* the barrier —
//! it drains the per-shard block queues, joins the worker balancers'
//! epoch work, and re-raises any worker panic. The `order_secs` metric
//! therefore includes the drain wait: with async shards, observe-side
//! time shrinks to a gather + enqueue and any residual balancing cost
//! shows up at the boundary instead.

pub mod checkpoint;
pub mod metrics;

pub use metrics::{EpochMetrics, MetricsSink};

use anyhow::{Context, Result};

use crate::config::{LrSchedule, TrainConfig};
use crate::data::loader::{HostBatch, Loader, Microbatch};
use crate::data::Dataset;
use crate::model::build_datasets;
use crate::optim::{GradAccumulator, MomentumSgd, Scheduler};
use crate::ordering::{build_policy, GradBlock, OrderPolicy};
use crate::runtime::{EvalExecutor, GradExecutor, Runtime};
use crate::util::timer::Stopwatch;

/// Eval-gating predicate shared by the trainers: evaluate every
/// `eval_every` epochs and always on the final epoch — unless
/// `eval_every == 0`, which disables evaluation entirely (the old
/// `a && b || c` precedence evaluated the final epoch even then).
pub(crate) fn should_eval(
    eval_every: usize,
    epoch: usize,
    epochs: usize,
) -> bool {
    eval_every > 0
        && ((epoch + 1) % eval_every == 0 || epoch + 1 == epochs)
}

/// Outcome of a full training run.
#[derive(Clone, Debug)]
pub struct TrainResult {
    /// The config's run identity string.
    pub run_id: String,
    /// Per-epoch metrics, in order.
    pub epochs: Vec<EpochMetrics>,
    /// The ordering the policy would use next (Fig. 3's "retrain" order).
    pub final_order: Vec<usize>,
    /// Ordering-state bytes at the end (Table 1).
    pub order_state_bytes: usize,
    /// Aggregated per-shard link counters for transported CD-GraB
    /// policies (stalls, bytes moved to/from shard workers); `None` for
    /// unsharded orderings. Lets sync / channel / tcp runs report
    /// comparable backpressure numbers.
    pub transport: Option<crate::ordering::transport::TransportStats>,
    /// Per-epoch shard topology plans for sharded orderings: entry `e`
    /// produced epoch `e`'s order, plus one trailing entry for the
    /// plan behind [`TrainResult::final_order`] (so a run of E epochs
    /// records E+1 plans); `None` for unsharded orderings. For an
    /// `--elastic` run this log is the replay recipe: pin the recorded
    /// weights (`--weights`, or a schedule at policy level) and the
    /// run re-executes bit-for-bit (docs/determinism.md contract 6).
    pub topology: Option<Vec<crate::ordering::Topology>>,
}

impl TrainResult {
    /// Train loss of the last epoch (NaN when no epochs ran).
    pub fn final_train_loss(&self) -> f64 {
        self.epochs.last().map(|e| e.train_loss).unwrap_or(f64::NAN)
    }
}

/// The synchronous trainer (the threaded pipeline variant lives in
/// [`crate::pipeline`] and shares this struct's components).
pub struct Trainer {
    /// The validated run configuration.
    pub cfg: TrainConfig,
    /// Training dataset (ordering units).
    pub train_ds: Dataset,
    /// Held-out evaluation dataset.
    pub eval_ds: Dataset,
    grad_exec: GradExecutor,
    eval_exec: EvalExecutor,
    /// The example-ordering policy under test.
    pub policy: Box<dyn OrderPolicy>,
    opt: MomentumSgd,
    sched: Scheduler,
    /// Flattened model parameters (layout per the artifact manifest).
    pub params: Vec<f32>,
    sink: Option<MetricsSink>,
    /// First epoch [`Trainer::run`] will execute: 0 for a fresh run,
    /// `ckpt.epoch + 1` after [`Trainer::restore`].
    start_epoch: usize,
}

impl Trainer {
    /// Build a trainer from config against an opened runtime.
    /// `retrain_order` feeds the Fig. 3 "Retrain from GraB" policy.
    pub fn new(
        cfg: TrainConfig,
        rt: &Runtime,
        retrain_order: Option<Vec<usize>>,
    ) -> Result<Trainer> {
        let model_name = cfg.task.model_name();
        let grad_exec = rt
            .grad_executor(model_name)
            .with_context(|| format!("grad executor for {model_name}"))?;
        let eval_exec = rt.eval_executor(model_name)?;
        let params = rt.init_params(model_name)?;
        let d = grad_exec.dim();
        let (train_ds, eval_ds) = build_datasets(&cfg);
        let policy =
            build_policy(&cfg, train_ds.len(), d, retrain_order)?;
        let opt = MomentumSgd::new(d, cfg.momentum, cfg.weight_decay);
        let sched = match cfg.lr_schedule {
            LrSchedule::Constant => Scheduler::constant(cfg.lr),
            LrSchedule::ReduceOnPlateau { factor, patience, threshold } => {
                Scheduler::reduce_on_plateau(
                    cfg.lr, factor, patience, threshold)
            }
        };
        let sink = match &cfg.metrics_out {
            Some(path) => Some(MetricsSink::create(path)?),
            None => None,
        };
        Ok(Trainer {
            cfg,
            train_ds,
            eval_ds,
            grad_exec,
            eval_exec,
            policy,
            opt,
            sched,
            params,
            sink,
            start_epoch: 0,
        })
    }

    /// Open/create the configured run directory, applying `--resume`
    /// (fingerprint-gated restore of the newest snapshot). `None` when
    /// checkpointing is off.
    fn prepare_run_dir(&mut self) -> Result<Option<checkpoint::RunDir>> {
        let Some(dir) = self.cfg.checkpoint_dir.clone() else {
            return Ok(None);
        };
        let dir = std::path::PathBuf::from(dir);
        let manifest = checkpoint::manifest_for(
            self.cfg.fingerprint(),
            &self.cfg.run_id(),
            self.cfg.ordering.name(),
            self.cfg.kernels.name(),
            self.cfg.checkpoint_every as u64,
        );
        if self.cfg.resume {
            let rd = checkpoint::RunDir::open(&dir)?;
            rd.check_fingerprint(manifest.fingerprint)?;
            if let Some(ckpt) = rd.load_latest()? {
                eprintln!(
                    "[grab] resuming {} from epoch {} ({})",
                    self.cfg.run_id(),
                    ckpt.epoch,
                    rd.path().display()
                );
                self.restore(&ckpt)?;
            }
            Ok(Some(rd))
        } else {
            Ok(Some(checkpoint::RunDir::create(&dir, manifest)?))
        }
    }

    /// Train for the configured number of epochs (from
    /// [`Checkpoint::epoch`]` + 1` after a restore), snapshotting into
    /// the run directory every `checkpoint_every` epochs when one is
    /// configured.
    ///
    /// [`Checkpoint::epoch`]: checkpoint::Checkpoint::epoch
    pub fn run(&mut self) -> Result<TrainResult> {
        let run_dir = self.prepare_run_dir()?;
        let start = self.start_epoch.min(self.cfg.epochs);
        let mut epochs =
            Vec::with_capacity(self.cfg.epochs - start);
        for epoch in start..self.cfg.epochs {
            let m = self.run_epoch(epoch)?;
            if let Some(sink) = &mut self.sink {
                sink.push(&m)?;
            }
            epochs.push(m);
            if let Some(rd) = &run_dir {
                let every = self.cfg.checkpoint_every.max(1);
                if (epoch + 1) % every == 0
                    || epoch + 1 == self.cfg.epochs
                {
                    let snap = self.snapshot(epoch);
                    rd.save_epoch(
                        &snap,
                        checkpoint::DEFAULT_KEEP_LAST,
                    )?;
                }
            }
        }
        let final_order = self.policy.epoch_order(self.cfg.epochs).to_vec();
        Ok(TrainResult {
            run_id: self.cfg.run_id(),
            epochs,
            final_order,
            order_state_bytes: self.policy.state_bytes(),
            transport: self.policy.transport_stats(),
            topology: self.policy.topology_log().map(|l| l.to_vec()),
        })
    }

    /// One epoch: visit every unit in the policy's order, stream the
    /// valid rows of each executor gradient buffer through the policy as
    /// one zero-copy [`GradBlock`], step the optimizer per accumulation
    /// window.
    pub fn run_epoch(&mut self, epoch: usize) -> Result<EpochMetrics> {
        let sw_epoch = Stopwatch::start();
        let b = self.grad_exec.batch();
        let d = self.grad_exec.dim();
        let n = self.train_ds.len();
        let lr = self.sched.lr();
        let wants_grads = self.policy.wants_grads();

        let mbs: Vec<Microbatch> = {
            let order = self.policy.epoch_order(epoch);
            debug_assert_eq!(order.len(), n);
            Loader::new(order, b).collect()
        };

        let mut accum = GradAccumulator::new(d, b * self.cfg.accum_steps);
        let mut host = HostBatch::default();
        let mut losses: Vec<f32> = Vec::new();
        let mut grads: Vec<f32> = Vec::new();
        let mut loss_sum = 0.0f64;
        let mut grad_secs = 0.0f64;
        let mut order_secs = 0.0f64;
        let mut steps = 0usize;

        for mb in mbs {
            host.fill(&self.train_ds, &mb);
            let sw = Stopwatch::start();
            self.grad_exec.run(
                &self.params,
                &host.x_f32,
                &host.x_i32,
                &host.y,
                &mut losses,
                &mut grads,
            )?;
            grad_secs += sw.secs();

            // One policy dispatch per microbatch: the valid prefix of the
            // executor buffer viewed as a [valid × d] block (padding rows
            // are never balanced).
            if wants_grads && mb.valid > 0 {
                let sw_o = Stopwatch::start();
                self.policy.observe_block(
                    mb.offset..mb.offset + mb.valid,
                    &GradBlock::new(&grads[..mb.valid * d], d),
                );
                order_secs += sw_o.secs();
            }
            for i in 0..mb.valid {
                let g = &grads[i * d..(i + 1) * d];
                loss_sum += losses[i] as f64;
                if let Some(mean) = accum.push(g) {
                    let mut mean = mean.to_vec();
                    crate::optim::clip_global_norm(
                        &mut mean, self.cfg.clip_norm);
                    self.opt.step(&mut self.params, &mean, lr);
                    accum.clear();
                    steps += 1;
                }
            }
        }
        // Flush a final partial window so every example contributes.
        if let Some(mean) = accum.flush() {
            let mut mean = mean.to_vec();
            crate::optim::clip_global_norm(&mut mean, self.cfg.clip_norm);
            self.opt.step(&mut self.params, &mean, lr);
            steps += 1;
        }

        // Epoch-boundary barrier: for async sharded policies this drains
        // the shard queues and joins the workers' epoch (see module docs).
        let sw_o = Stopwatch::start();
        self.policy.epoch_end();
        order_secs += sw_o.secs();

        let train_loss = loss_sum / n as f64;
        self.sched.epoch_feedback(train_loss);

        let (eval_loss, eval_acc) =
            if should_eval(self.cfg.eval_every, epoch, self.cfg.epochs) {
                let (l, a) = self.evaluate()?;
                (Some(l), Some(a))
            } else {
                (None, None)
            };

        Ok(EpochMetrics {
            epoch,
            train_loss,
            eval_loss,
            eval_acc,
            lr,
            optimizer_steps: steps,
            grad_secs,
            order_secs,
            epoch_secs: sw_epoch.secs(),
            order_state_bytes: self.policy.state_bytes(),
        })
    }

    /// Snapshot the run for resumption: params, momentum, scheduler
    /// counters, the policy's order, and its opaque epoch-boundary
    /// state ([`crate::ordering::OrderPolicy::save_state`]). Must be
    /// called between epochs (after `run_epoch(epoch)` returned) —
    /// both the re-borrowed order and the policy state are cache hits
    /// there, so snapshotting never perturbs the run it records.
    pub fn snapshot(&mut self, epoch: usize) -> checkpoint::Checkpoint {
        let (lr, best, bad) = self.sched.state();
        checkpoint::Checkpoint {
            epoch: epoch as u64,
            params: self.params.clone(),
            velocity: self.opt.velocity().to_vec(),
            order: self
                .policy
                .epoch_order(epoch)
                .iter()
                .map(|&i| i as u64)
                .collect(),
            sched: Some((lr, best, bad as u64)),
            policy_state: self.policy.save_state(),
        }
    }

    /// Restore the full run state from a snapshot: params, momentum,
    /// scheduler counters, and the ordering policy's epoch-boundary
    /// state — then arm [`Trainer::run`] to continue at
    /// `ckpt.epoch + 1`. A v1 snapshot (no policy state) falls back to
    /// seeding the policy's next permutation from the recorded order;
    /// a gradient-driven policy that cannot adopt it is refused with
    /// [`checkpoint::CheckpointError::PolicyNotResumable`] (see
    /// [`checkpoint::restore_policy`]).
    pub fn restore(&mut self, ckpt: &checkpoint::Checkpoint)
        -> crate::Result<()> {
        anyhow::ensure!(ckpt.params.len() == self.params.len(),
                        "checkpoint dim mismatch");
        self.params.copy_from_slice(&ckpt.params);
        self.opt.set_velocity(&ckpt.velocity)?;
        if let Some((lr, best, bad)) = ckpt.sched {
            self.sched.restore_state(lr, best, bad as usize);
        }
        // Shared typed resume gate: restores saved policy state, seeds
        // legacy order-only snapshots, and *refuses* (typed
        // `PolicyNotResumable`) a gradient-driven policy that would
        // silently restart its ordering — never a warning-and-diverge.
        checkpoint::restore_policy(self.policy.as_mut(), ckpt)?;
        self.start_epoch = ckpt.epoch as usize + 1;
        Ok(())
    }

    /// Mean eval loss and accuracy over the eval dataset (full E-batches
    /// only; the eval set size should be a multiple of E for exactness).
    pub fn evaluate(&mut self) -> Result<(f64, f64)> {
        let e = self.eval_exec.batch();
        let n = self.eval_ds.len();
        let mut host = HostBatch::default();
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut seen = 0usize;
        let order: Vec<usize> = (0..n).collect();
        for mb in Loader::new(&order, e) {
            if mb.valid < e {
                break; // drop ragged tail
            }
            host.fill(&self.eval_ds, &mb);
            let (l, c) = self.eval_exec.run(
                &self.params, &host.x_f32, &host.x_i32, &host.y)?;
            loss_sum += l as f64;
            correct += c as f64;
            seen += e;
        }
        anyhow::ensure!(seen > 0, "eval set smaller than eval batch {e}");
        Ok((loss_sum / seen as f64, correct / seen as f64))
    }
}

#[cfg(test)]
mod tests {
    use super::should_eval;

    #[test]
    fn eval_every_zero_never_evaluates() {
        // Regression: the old `a && b || c` precedence evaluated the
        // final epoch even with eval_every == 0.
        for epoch in 0..5 {
            assert!(!should_eval(0, epoch, 5), "epoch {epoch}");
        }
    }

    #[test]
    fn eval_every_k_hits_multiples_and_final_epoch() {
        let hits: Vec<usize> =
            (0..7).filter(|&e| should_eval(3, e, 7)).collect();
        // Epochs are 0-based: (e+1) % 3 == 0 -> e in {2, 5}, plus the
        // final epoch e = 6.
        assert_eq!(hits, vec![2, 5, 6]);
    }

    #[test]
    fn eval_every_one_evaluates_every_epoch() {
        assert!((0..4).all(|e| should_eval(1, e, 4)));
    }
}
