//! Per-epoch metrics and the CSV sink every run can stream them to.

use std::path::Path;

use anyhow::Result;

use crate::util::ser::{fmt_f, CsvWriter};

/// One epoch's measurements.
#[derive(Clone, Debug)]
pub struct EpochMetrics {
    /// 0-based epoch index.
    pub epoch: usize,
    /// Mean per-unit train loss over the epoch (computed on the fly, i.e.
    /// at the parameters current when each unit was visited — the same
    /// "training loss" curve the paper plots).
    pub train_loss: f64,
    /// Mean eval loss, when this epoch was evaluated.
    pub eval_loss: Option<f64>,
    /// Eval accuracy, when this epoch was evaluated.
    pub eval_acc: Option<f64>,
    /// Learning rate in effect this epoch.
    pub lr: f64,
    /// Optimizer steps taken (accumulation windows flushed).
    pub optimizer_steps: usize,
    /// Seconds in the PJRT grad executor.
    pub grad_secs: f64,
    /// Seconds in the ordering policy (observe + epoch_end) — the ordering
    /// overhead column of Table 1.
    pub order_secs: f64,
    /// Wall-clock seconds for the whole epoch.
    pub epoch_secs: f64,
    /// Ordering-policy state bytes at the end of the epoch (Table 1).
    pub order_state_bytes: usize,
}

/// Column names for [`EpochMetrics::csv_row`], in order.
pub const CSV_HEADER: [&str; 10] = [
    "epoch",
    "train_loss",
    "eval_loss",
    "eval_acc",
    "lr",
    "optimizer_steps",
    "grad_secs",
    "order_secs",
    "epoch_secs",
    "order_state_bytes",
];

impl EpochMetrics {
    /// The metrics as CSV cells, matching [`CSV_HEADER`].
    pub fn csv_row(&self) -> Vec<String> {
        vec![
            self.epoch.to_string(),
            fmt_f(self.train_loss),
            self.eval_loss.map(fmt_f).unwrap_or_default(),
            self.eval_acc.map(fmt_f).unwrap_or_default(),
            fmt_f(self.lr),
            self.optimizer_steps.to_string(),
            fmt_f(self.grad_secs),
            fmt_f(self.order_secs),
            fmt_f(self.epoch_secs),
            self.order_state_bytes.to_string(),
        ]
    }

    /// One-line log form.
    pub fn line(&self, tag: &str) -> String {
        let eval = match (self.eval_loss, self.eval_acc) {
            (Some(l), Some(a)) => {
                format!(" eval_loss={l:.4} eval_acc={a:.3}")
            }
            _ => String::new(),
        };
        format!(
            "[{tag}] epoch {:>3}  train_loss={:.4}{eval}  lr={:.4} \
             grad={:.2}s order={:.3}s ({}B state)",
            self.epoch,
            self.train_loss,
            self.lr,
            self.grad_secs,
            self.order_secs,
            self.order_state_bytes,
        )
    }
}

/// CSV metrics sink.
pub struct MetricsSink {
    writer: CsvWriter,
}

impl MetricsSink {
    /// Create (truncate) the CSV at `path` and write the header.
    pub fn create(path: impl AsRef<Path>) -> Result<MetricsSink> {
        Ok(MetricsSink {
            writer: CsvWriter::create(path.as_ref(), &CSV_HEADER)?,
        })
    }

    /// Append one epoch row and flush it to disk.
    pub fn push(&mut self, m: &EpochMetrics) -> Result<()> {
        self.writer.row(&m.csv_row())?;
        self.writer.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> EpochMetrics {
        EpochMetrics {
            epoch: 1,
            train_loss: 0.5,
            eval_loss: Some(0.6),
            eval_acc: Some(0.9),
            lr: 0.1,
            optimizer_steps: 10,
            grad_secs: 1.0,
            order_secs: 0.01,
            epoch_secs: 1.1,
            order_state_bytes: 1234,
        }
    }

    #[test]
    fn csv_row_matches_header_len() {
        assert_eq!(sample().csv_row().len(), CSV_HEADER.len());
    }

    #[test]
    fn sink_writes_rows() {
        let dir = std::env::temp_dir().join("grab_metrics_test");
        let path = dir.join("m.csv");
        {
            let mut sink = MetricsSink::create(&path).unwrap();
            sink.push(&sample()).unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        assert!(text.starts_with("epoch,train_loss"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn line_includes_eval_when_present() {
        let m = sample();
        assert!(m.line("x").contains("eval_acc"));
        let mut m2 = m;
        m2.eval_loss = None;
        m2.eval_acc = None;
        assert!(!m2.line("x").contains("eval_acc"));
    }
}
