//! Durable run state: versioned on-disk run directories that a killed
//! training process can resume from **bit-identically** (determinism
//! contract 8 in `docs/determinism.md`).
//!
//! A run directory holds a JSON manifest plus per-epoch binary
//! snapshots, written atomically (temp file + rename) and retained to
//! the last `keep_last`:
//!
//! ```text
//! <dir>/MANIFEST.json        schema version, config fingerprint,
//!                            run id, policy, kernel tier, git rev
//! <dir>/epoch-000007.ckpt    snapshot taken after epoch 7
//! ```
//!
//! Snapshot format (little-endian):
//! ```text
//! magic "GRABCKPT" | u32 version | u32 crc32(payload) | payload
//! v1 payload: u64 epoch | u64 d | f32[d] params | f32[d] velocity
//!           | u64 n | u64[n] order
//! v2 payload: u64 epoch
//!           | u64 d | f32[d] params | u64 d | f32[d] velocity
//!           | u32 sched_tag (1 ⇒ f64 lr | f64 best | u64 bad_epochs)
//!           | u64 n | u64[n] order
//!           | u32 policy_tag (1 ⇒ u64 len | opaque policy bytes from
//!             [`crate::ordering::OrderPolicy::save_state`])
//! ```
//!
//! v2 carries everything the replay contracts need beyond the model:
//! the LR scheduler's plateau counters and the ordering policy's
//! epoch-boundary state (GraB's stale mean, the balancer RNG stream,
//! CD-GraB's per-shard local orders and topology log). v1 files still
//! load — their extra fields come back as `None` and a resume falls
//! back to seeding the policy with the recorded permutation only.
//!
//! Every failure is a typed [`CheckpointError`]; a corrupt, truncated,
//! or future-versioned file can never panic or silently resume wrong.

use std::fmt;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use crate::util::ser::{self, ByteReader, Json, WireError};

const MAGIC: &[u8; 8] = b"GRABCKPT";

/// Highest snapshot format this build writes and reads.
pub const SNAPSHOT_VERSION: u32 = 2;

/// Highest manifest schema this build understands.
pub const MANIFEST_VERSION: u32 = 1;

/// Manifest file name inside a run directory.
pub const MANIFEST_FILE: &str = "MANIFEST.json";

/// Default retention: snapshots kept per run directory.
pub const DEFAULT_KEEP_LAST: usize = 3;

/// Typed checkpoint failure — the negative-path contract: every bad
/// input (torn write, byte flip, wrong directory, version from a newer
/// build, config drift, pruned epoch) maps to a variant here, never a
/// panic and never a silently-wrong resume.
#[derive(Debug)]
pub enum CheckpointError {
    /// Filesystem failure (open/create/rename/read/write).
    Io(std::io::Error),
    /// The path is not a grab checkpoint (bad magic / no manifest).
    NotACheckpoint(PathBuf),
    /// File written by a newer build than this one can read.
    VersionFromTheFuture {
        /// Version found in the file.
        found: u32,
        /// Highest version this build supports.
        supported: u32,
    },
    /// Stored CRC does not match the payload (corruption/byte flip).
    BadChecksum(PathBuf),
    /// File ended before the declared payload did.
    Truncated(PathBuf),
    /// Payload parsed but left unconsumed trailing bytes.
    TrailingBytes(PathBuf),
    /// Payload contents inconsistent with the declared schema.
    Malformed(String),
    /// Manifest fingerprint differs from the resuming config's.
    FingerprintMismatch {
        /// Fingerprint recorded in the manifest.
        manifest: u32,
        /// Fingerprint of the config trying to resume.
        config: u32,
    },
    /// The requested epoch snapshot is absent from the directory
    /// (outside the retention window, or never written).
    MissingEpoch {
        /// The epoch asked for.
        epoch: u64,
        /// The run directory searched.
        dir: PathBuf,
    },
    /// The ordering policy rejected its saved state on restore.
    PolicyState(String),
    /// The snapshot carries no policy state and the (gradient-driven)
    /// policy cannot adopt the recorded order either — resuming would
    /// silently restart its ordering from scratch while claiming a
    /// clean resume, so it is refused instead.
    PolicyNotResumable(String),
}

impl fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CheckpointError::Io(e) => write!(f, "checkpoint io: {e}"),
            CheckpointError::NotACheckpoint(p) => {
                write!(f, "{} is not a grab checkpoint", p.display())
            }
            CheckpointError::VersionFromTheFuture {
                found,
                supported,
            } => write!(
                f,
                "checkpoint version {found} is from the future \
                 (this build reads up to {supported})"
            ),
            CheckpointError::BadChecksum(p) => write!(
                f,
                "checkpoint {} failed CRC check (corrupt/truncated)",
                p.display()
            ),
            CheckpointError::Truncated(p) => {
                write!(f, "checkpoint {} is truncated", p.display())
            }
            CheckpointError::TrailingBytes(p) => write!(
                f,
                "trailing bytes in checkpoint {}",
                p.display()
            ),
            CheckpointError::Malformed(why) => {
                write!(f, "malformed checkpoint: {why}")
            }
            CheckpointError::FingerprintMismatch {
                manifest,
                config,
            } => write!(
                f,
                "config fingerprint {config:#010x} does not match the \
                 run directory's {manifest:#010x} — the resuming \
                 config differs from the one that wrote it"
            ),
            CheckpointError::MissingEpoch { epoch, dir } => write!(
                f,
                "no snapshot for epoch {epoch} in {} (outside the \
                 retention window?)",
                dir.display()
            ),
            CheckpointError::PolicyState(why) => {
                write!(f, "policy state restore failed: {why}")
            }
            CheckpointError::PolicyNotResumable(name) => write!(
                f,
                "policy '{name}' is not resumable from this snapshot: \
                 it carries no saved ordering state and cannot adopt \
                 the recorded order (resuming would silently restart \
                 its ordering)"
            ),
        }
    }
}

impl std::error::Error for CheckpointError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CheckpointError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for CheckpointError {
    fn from(e: std::io::Error) -> CheckpointError {
        CheckpointError::Io(e)
    }
}

/// Map a payload-parse [`WireError`] onto the checkpoint error space.
fn wire_err(e: WireError, path: &Path) -> CheckpointError {
    match e {
        WireError::Truncated { .. } => {
            CheckpointError::Truncated(path.to_path_buf())
        }
        other => CheckpointError::Malformed(other.to_string()),
    }
}

/// CRC-32 (IEEE 802.3, reflected) — implemented in-tree; the vendored dep
/// closure is reserved for the xla crate.
pub fn crc32(data: &[u8]) -> u32 {
    let mut table = [0u32; 256];
    for (i, entry) in table.iter_mut().enumerate() {
        let mut c = i as u32;
        for _ in 0..8 {
            c = if c & 1 != 0 {
                0xEDB8_8320 ^ (c >> 1)
            } else {
                c >> 1
            };
        }
        *entry = c;
    }
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc = table[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    crc ^ 0xFFFF_FFFF
}

/// Write `bytes` to `path` atomically: temp file in the same directory,
/// `sync_all`, then rename — a crash mid-write never corrupts the
/// previous contents of `path`.
fn write_atomic(path: &Path, bytes: &[u8]) -> Result<(), CheckpointError> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Restore an ordering policy's epoch-boundary state from a snapshot —
/// the one shared resume gate (trainer and `exp cdgrab` both route
/// through it, so the refusal semantics cannot diverge):
///
/// * snapshots with policy state restore it (typed
///   [`CheckpointError::PolicyState`] on rejection);
/// * legacy order-only snapshots seed the recorded permutation where
///   the policy supports that;
/// * a gradient-driven policy that can do neither is **refused** with
///   [`CheckpointError::PolicyNotResumable`] — before this gate a
///   greedy resume silently restarted its ordering from scratch;
/// * stateless policies (order derivable from config alone) resume
///   from their freshly constructed state, which is exact for them.
pub fn restore_policy(
    policy: &mut dyn crate::ordering::OrderPolicy,
    ckpt: &Checkpoint,
) -> Result<(), CheckpointError> {
    if let Some(bytes) = &ckpt.policy_state {
        return policy
            .restore_state(bytes)
            .map_err(CheckpointError::PolicyState);
    }
    if ckpt.order.is_empty() {
        return Ok(());
    }
    let order: Vec<usize> =
        ckpt.order.iter().map(|&i| i as usize).collect();
    if policy.restore_order(&order) {
        Ok(())
    } else if policy.wants_grads() {
        Err(CheckpointError::PolicyNotResumable(
            policy.name().to_string(),
        ))
    } else {
        // Config-derivable order (Sequential, ShuffleOnce, FixedOrder):
        // the reconstructed policy already follows the snapshot's
        // permutation, so there is nothing to restore.
        Ok(())
    }
}

/// One resumable snapshot.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    /// Epoch the snapshot was taken after.
    pub epoch: u64,
    /// Model parameters (flattened, layout per the artifact manifest).
    pub params: Vec<f32>,
    /// Optimizer momentum buffer, same layout as `params`.
    pub velocity: Vec<f32>,
    /// The ordering policy's next epoch permutation.
    pub order: Vec<u64>,
    /// LR-scheduler state `(lr, best_loss, bad_epochs)`; `None` in v1
    /// files (resume keeps the freshly-constructed scheduler).
    pub sched: Option<(f64, f64, u64)>,
    /// Opaque epoch-boundary policy state from
    /// [`crate::ordering::OrderPolicy::save_state`]; `None` in v1
    /// files or for policies whose state is derivable from config.
    pub policy_state: Option<Vec<u8>>,
}

impl Checkpoint {
    /// Serialize (format v2) atomically to `path`.
    pub fn save(&self, path: &Path) -> Result<(), CheckpointError> {
        if self.params.len() != self.velocity.len() {
            return Err(CheckpointError::Malformed(format!(
                "params/velocity length mismatch: {} vs {}",
                self.params.len(),
                self.velocity.len()
            )));
        }
        let mut payload = Vec::with_capacity(
            64 + self.params.len() * 8 + self.order.len() * 8
                + self.policy_state.as_ref().map_or(0, |b| b.len()),
        );
        ser::put_u64(&mut payload, self.epoch);
        ser::put_f32_slice(&mut payload, &self.params);
        ser::put_f32_slice(&mut payload, &self.velocity);
        match self.sched {
            Some((lr, best, bad)) => {
                ser::put_u32(&mut payload, 1);
                ser::put_f64(&mut payload, lr);
                ser::put_f64(&mut payload, best);
                ser::put_u64(&mut payload, bad);
            }
            None => ser::put_u32(&mut payload, 0),
        }
        ser::put_u64(&mut payload, self.order.len() as u64);
        for &v in &self.order {
            ser::put_u64(&mut payload, v);
        }
        match &self.policy_state {
            Some(bytes) => {
                ser::put_u32(&mut payload, 1);
                ser::put_u64(&mut payload, bytes.len() as u64);
                payload.extend_from_slice(bytes);
            }
            None => ser::put_u32(&mut payload, 0),
        }

        let mut file = Vec::with_capacity(16 + payload.len());
        file.extend_from_slice(MAGIC);
        file.extend_from_slice(&SNAPSHOT_VERSION.to_le_bytes());
        file.extend_from_slice(&crc32(&payload).to_le_bytes());
        file.extend_from_slice(&payload);
        write_atomic(path, &file)
    }

    /// Read + verify (magic, version, CRC) a snapshot from `path`.
    /// Accepts format v1 and v2; anything newer is
    /// [`CheckpointError::VersionFromTheFuture`].
    pub fn load(path: &Path) -> Result<Checkpoint, CheckpointError> {
        let mut f = std::fs::File::open(path)?;
        let mut header = [0u8; 16];
        if let Err(e) = f.read_exact(&mut header) {
            return Err(
                if e.kind() == std::io::ErrorKind::UnexpectedEof {
                    CheckpointError::Truncated(path.to_path_buf())
                } else {
                    CheckpointError::Io(e)
                },
            );
        }
        if &header[0..8] != MAGIC {
            return Err(CheckpointError::NotACheckpoint(
                path.to_path_buf(),
            ));
        }
        let version =
            u32::from_le_bytes(header[8..12].try_into().unwrap());
        if version == 0 || version > SNAPSHOT_VERSION {
            return Err(CheckpointError::VersionFromTheFuture {
                found: version,
                supported: SNAPSHOT_VERSION,
            });
        }
        let want_crc =
            u32::from_le_bytes(header[12..16].try_into().unwrap());
        let mut payload = Vec::new();
        f.read_to_end(&mut payload)?;
        if crc32(&payload) != want_crc {
            return Err(CheckpointError::BadChecksum(
                path.to_path_buf(),
            ));
        }
        let mut r = ByteReader::new(&payload);
        let ckpt = if version == 1 {
            Checkpoint::parse_v1(&mut r)
        } else {
            Checkpoint::parse_v2(&mut r)
        }
        .map_err(|e| wire_err(e, path))?;
        if r.remaining() != 0 {
            return Err(CheckpointError::TrailingBytes(
                path.to_path_buf(),
            ));
        }
        Ok(ckpt)
    }

    fn parse_v1(r: &mut ByteReader) -> Result<Checkpoint, WireError> {
        let epoch = r.u64()?;
        // v1 stored one shared dim prefix and raw (unprefixed) f32s.
        let d = r.len(r.remaining() / 4)?;
        let mut params = Vec::with_capacity(d);
        for _ in 0..d {
            let b = r.take(4)?;
            params.push(f32::from_le_bytes(b.try_into().unwrap()));
        }
        let mut velocity = Vec::with_capacity(d);
        for _ in 0..d {
            let b = r.take(4)?;
            velocity.push(f32::from_le_bytes(b.try_into().unwrap()));
        }
        let n = r.len(r.remaining() / 8)?;
        let mut order = Vec::with_capacity(n);
        for _ in 0..n {
            order.push(r.u64()?);
        }
        Ok(Checkpoint {
            epoch,
            params,
            velocity,
            order,
            sched: None,
            policy_state: None,
        })
    }

    fn parse_v2(r: &mut ByteReader) -> Result<Checkpoint, WireError> {
        let epoch = r.u64()?;
        let params = r.f32_slice(usize::MAX)?;
        let velocity = r.f32_slice(usize::MAX)?;
        let sched = match r.u32()? {
            0 => None,
            1 => Some((r.f64()?, r.f64()?, r.u64()?)),
            t => {
                return Err(WireError::Malformed(format!(
                    "unknown scheduler tag {t}"
                )))
            }
        };
        let n = r.len(r.remaining() / 8)?;
        let mut order = Vec::with_capacity(n);
        for _ in 0..n {
            order.push(r.u64()?);
        }
        let policy_state = match r.u32()? {
            0 => None,
            1 => {
                let len = r.len(r.remaining())?;
                Some(r.take(len)?.to_vec())
            }
            t => {
                return Err(WireError::Malformed(format!(
                    "unknown policy-state tag {t}"
                )))
            }
        };
        Ok(Checkpoint {
            epoch,
            params,
            velocity,
            order,
            sched,
            policy_state,
        })
    }
}

/// The run directory's identity record: which config (by fingerprint)
/// wrote it, under which policy/kernel tier, at which code revision.
/// A resume refuses the directory unless the fingerprint matches.
#[derive(Clone, Debug, PartialEq)]
pub struct Manifest {
    /// Manifest schema version ([`MANIFEST_VERSION`] when written).
    pub schema_version: u32,
    /// [`crate::config::TrainConfig::fingerprint`] of the writing run.
    pub fingerprint: u32,
    /// Human-readable run identity (`TrainConfig::run_id`).
    pub run_id: String,
    /// Ordering policy name the run was launched with.
    pub policy: String,
    /// Balance-kernel tier name (informational — every tier is
    /// bit-identical per contract 7, so resume does not gate on it).
    pub kernel: String,
    /// `git rev-parse --short HEAD` at write time (informational).
    pub git_rev: String,
    /// Snapshot cadence the run was launched with.
    pub checkpoint_every: u64,
}

impl Manifest {
    /// Serialize to the deterministic (key-sorted) JSON layout.
    pub fn to_json(&self) -> Json {
        ser::obj(vec![
            (
                "schema_version",
                Json::Num(self.schema_version as f64),
            ),
            ("fingerprint", Json::Num(self.fingerprint as f64)),
            ("run_id", Json::Str(self.run_id.clone())),
            ("policy", Json::Str(self.policy.clone())),
            ("kernel", Json::Str(self.kernel.clone())),
            ("git_rev", Json::Str(self.git_rev.clone())),
            (
                "checkpoint_every",
                Json::Num(self.checkpoint_every as f64),
            ),
        ])
    }

    /// Parse a manifest, refusing schemas from the future.
    pub fn from_json(j: &Json) -> Result<Manifest, CheckpointError> {
        let field = |k: &str| -> Result<&Json, CheckpointError> {
            j.get(k).map_err(|e| {
                CheckpointError::Malformed(format!("manifest: {e}"))
            })
        };
        let num = |k: &str| -> Result<u64, CheckpointError> {
            field(k)?.as_f64().map(|x| x as u64).map_err(|e| {
                CheckpointError::Malformed(format!("manifest: {e}"))
            })
        };
        let text = |k: &str| -> Result<String, CheckpointError> {
            field(k)?.as_str().map(str::to_string).map_err(|e| {
                CheckpointError::Malformed(format!("manifest: {e}"))
            })
        };
        let schema_version = num("schema_version")? as u32;
        if schema_version == 0 || schema_version > MANIFEST_VERSION {
            return Err(CheckpointError::VersionFromTheFuture {
                found: schema_version,
                supported: MANIFEST_VERSION,
            });
        }
        Ok(Manifest {
            schema_version,
            fingerprint: num("fingerprint")? as u32,
            run_id: text("run_id")?,
            policy: text("policy")?,
            kernel: text("kernel")?,
            git_rev: text("git_rev")?,
            checkpoint_every: num("checkpoint_every")?,
        })
    }

    /// Read a manifest file (missing file ⇒
    /// [`CheckpointError::NotACheckpoint`] on the parent directory).
    pub fn from_file(path: &Path) -> Result<Manifest, CheckpointError> {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                let dir = path
                    .parent()
                    .unwrap_or(Path::new("."))
                    .to_path_buf();
                return Err(CheckpointError::NotACheckpoint(dir));
            }
            Err(e) => return Err(CheckpointError::Io(e)),
        };
        let j = Json::parse(&text).map_err(|e| {
            CheckpointError::Malformed(format!(
                "manifest {}: {e}",
                path.display()
            ))
        })?;
        Manifest::from_json(&j)
    }
}

/// `git rev-parse --short HEAD`, or `"unknown"` outside a work tree.
fn git_rev() -> String {
    // Miri cannot spawn processes; the checkpoint suite runs under it,
    // so take the same fallback a non-git checkout gets.
    if cfg!(miri) {
        return "unknown".to_string();
    }
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// Build a manifest for a run about to start writing snapshots.
pub fn manifest_for(
    fingerprint: u32,
    run_id: &str,
    policy: &str,
    kernel: &str,
    checkpoint_every: u64,
) -> Manifest {
    Manifest {
        schema_version: MANIFEST_VERSION,
        fingerprint,
        run_id: run_id.to_string(),
        policy: policy.to_string(),
        kernel: kernel.to_string(),
        git_rev: git_rev(),
        checkpoint_every,
    }
}

/// A versioned on-disk run directory: manifest + per-epoch snapshots
/// with retention. All writes are atomic; all reads are CRC-verified.
pub struct RunDir {
    dir: PathBuf,
    /// The directory's identity record.
    pub manifest: Manifest,
}

impl RunDir {
    /// Create (or re-initialize) a run directory, writing the manifest.
    pub fn create(
        dir: &Path,
        manifest: Manifest,
    ) -> Result<RunDir, CheckpointError> {
        std::fs::create_dir_all(dir)?;
        write_atomic(
            &dir.join(MANIFEST_FILE),
            manifest.to_json().to_string().as_bytes(),
        )?;
        Ok(RunDir { dir: dir.to_path_buf(), manifest })
    }

    /// Open an existing run directory, reading + validating its
    /// manifest (missing ⇒ [`CheckpointError::NotACheckpoint`]).
    pub fn open(dir: &Path) -> Result<RunDir, CheckpointError> {
        let manifest = Manifest::from_file(&dir.join(MANIFEST_FILE))?;
        Ok(RunDir { dir: dir.to_path_buf(), manifest })
    }

    /// The directory path.
    pub fn path(&self) -> &Path {
        &self.dir
    }

    /// Refuse to resume under a config whose fingerprint differs from
    /// the manifest's.
    pub fn check_fingerprint(
        &self,
        config: u32,
    ) -> Result<(), CheckpointError> {
        if self.manifest.fingerprint != config {
            return Err(CheckpointError::FingerprintMismatch {
                manifest: self.manifest.fingerprint,
                config,
            });
        }
        Ok(())
    }

    /// Snapshot path for `epoch` (`epoch-000007.ckpt`).
    pub fn epoch_path(&self, epoch: u64) -> PathBuf {
        self.dir.join(format!("epoch-{epoch:06}.ckpt"))
    }

    /// Epochs with a snapshot on disk, ascending.
    pub fn epochs(&self) -> Result<Vec<u64>, CheckpointError> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(&self.dir)? {
            let name = entry?.file_name();
            let Some(name) = name.to_str() else { continue };
            if let Some(e) = name
                .strip_prefix("epoch-")
                .and_then(|s| s.strip_suffix(".ckpt"))
                .and_then(|s| s.parse::<u64>().ok())
            {
                out.push(e);
            }
        }
        out.sort_unstable();
        Ok(out)
    }

    /// The newest snapshotted epoch, if any.
    pub fn latest_epoch(&self) -> Result<Option<u64>, CheckpointError> {
        Ok(self.epochs()?.last().copied())
    }

    /// Write `ckpt` under its epoch name, then prune snapshots beyond
    /// the newest `keep_last` (0 is treated as 1 — the snapshot just
    /// written always survives its own retention pass).
    pub fn save_epoch(
        &self,
        ckpt: &Checkpoint,
        keep_last: usize,
    ) -> Result<(), CheckpointError> {
        ckpt.save(&self.epoch_path(ckpt.epoch))?;
        let epochs = self.epochs()?;
        let keep = keep_last.max(1);
        if epochs.len() > keep {
            for &old in &epochs[..epochs.len() - keep] {
                std::fs::remove_file(self.epoch_path(old))?;
            }
        }
        Ok(())
    }

    /// Load the snapshot for `epoch`
    /// (absent ⇒ [`CheckpointError::MissingEpoch`]).
    pub fn load_epoch(
        &self,
        epoch: u64,
    ) -> Result<Checkpoint, CheckpointError> {
        let path = self.epoch_path(epoch);
        if !path.exists() {
            return Err(CheckpointError::MissingEpoch {
                epoch,
                dir: self.dir.clone(),
            });
        }
        Checkpoint::load(&path)
    }

    /// Load the newest snapshot, or `None` for an empty directory.
    pub fn load_latest(
        &self,
    ) -> Result<Option<Checkpoint>, CheckpointError> {
        match self.latest_epoch()? {
            Some(e) => Ok(Some(self.load_epoch(e)?)),
            None => Ok(None),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testdir::TestDir;

    fn sample() -> Checkpoint {
        Checkpoint {
            epoch: 7,
            params: vec![1.5, -2.25, 0.0, 3.75],
            velocity: vec![0.1, 0.2, -0.3, 0.4],
            order: vec![3, 1, 0, 2],
            sched: Some((0.05, 1.25, 2)),
            policy_state: Some(vec![9, 8, 7, 6, 5]),
        }
    }

    fn manifest() -> Manifest {
        manifest_for(0xDEAD_BEEF, "run-1", "grab", "scalar", 1)
    }

    #[test]
    fn roundtrip() {
        let dir = TestDir::new("ckpt_roundtrip");
        let path = dir.path().join("run.ckpt");
        let c = sample();
        c.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn roundtrip_without_optional_fields() {
        let dir = TestDir::new("ckpt_no_opt");
        let path = dir.path().join("run.ckpt");
        let c = Checkpoint { sched: None, policy_state: None, ..sample() };
        c.save(&path).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap(), c);
    }

    #[test]
    fn restore_policy_gates_each_resume_shape() {
        use crate::ordering::{GreedyOrder, OrderPolicy, Sequential};

        // Stateful snapshot → restore_state path.
        let mut greedy = GreedyOrder::new(4, 2);
        let state = greedy.save_state().unwrap();
        let mut fresh = GreedyOrder::new(4, 2);
        let ckpt = Checkpoint {
            epoch: 0,
            params: Vec::new(),
            velocity: Vec::new(),
            order: vec![2, 0, 3, 1],
            sched: None,
            policy_state: Some(state),
        };
        restore_policy(&mut fresh, &ckpt).unwrap();

        // Legacy order-only snapshot → a policy that can adopt it does.
        let legacy = Checkpoint { policy_state: None, ..ckpt.clone() };
        let mut fresh = GreedyOrder::new(4, 2);
        restore_policy(&mut fresh, &legacy).unwrap();
        assert_eq!(fresh.epoch_order(0), &[2, 0, 3, 1]);

        // A gradient-driven policy that can neither restore state nor
        // adopt the order is refused with the typed variant — the
        // silent-restart regression this gate exists for.
        struct NoResume;
        impl OrderPolicy for NoResume {
            fn name(&self) -> &'static str {
                "no-resume"
            }
            fn epoch_order(&mut self, _epoch: usize) -> &[usize] {
                &[]
            }
            fn observe_block(
                &mut self,
                _range: std::ops::Range<usize>,
                _block: &crate::ordering::GradBlock,
            ) {
            }
            fn epoch_end(&mut self) {}
            fn state_bytes(&self) -> usize {
                0
            }
            fn wants_grads(&self) -> bool {
                true
            }
        }
        let err = restore_policy(&mut NoResume, &legacy).unwrap_err();
        assert!(
            matches!(err, CheckpointError::PolicyNotResumable(_)),
            "{err}"
        );
        assert!(err.to_string().contains("not resumable"), "{err}");

        // Stateless policies resume from config-reconstructed state.
        let mut seq = Sequential::new(4);
        restore_policy(&mut seq, &legacy).unwrap();

        // Corrupt policy state maps to the PolicyState variant.
        let bad = Checkpoint {
            policy_state: Some(vec![0xFF; 3]),
            ..ckpt
        };
        let mut fresh = GreedyOrder::new(4, 2);
        let err = restore_policy(&mut fresh, &bad).unwrap_err();
        assert!(matches!(err, CheckpointError::PolicyState(_)), "{err}");
    }

    #[test]
    fn detects_corruption() {
        let dir = TestDir::new("ckpt_corrupt");
        let path = dir.path().join("run.ckpt");
        sample().save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(err.to_string().contains("CRC"), "{err}");
        assert!(matches!(err, CheckpointError::BadChecksum(_)));
    }

    #[test]
    fn detects_corruption_at_every_offset() {
        // A single byte flip anywhere in the file must surface as a
        // typed error (checksum, magic, version, or truncation —
        // depending on what the flip hit), never a wrong Checkpoint.
        let dir = TestDir::new("ckpt_flip_sweep");
        let path = dir.path().join("run.ckpt");
        let c = sample();
        c.save(&path).unwrap();
        let good = std::fs::read(&path).unwrap();
        for off in 0..good.len() {
            let mut bytes = good.clone();
            bytes[off] ^= 0x40;
            std::fs::write(&path, &bytes).unwrap();
            match Checkpoint::load(&path) {
                Err(_) => {}
                Ok(back) => {
                    // A flip in the CRC'd payload must be caught; only
                    // a flip that collides back to the same semantics
                    // could load, which CRC32 makes impossible for a
                    // single-bit-pattern flip.
                    panic!(
                        "byte flip at offset {off} loaded as {back:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn rejects_wrong_magic() {
        let dir = TestDir::new("ckpt_magic");
        let path = dir.path().join("bad.ckpt");
        std::fs::write(&path, b"NOTAGRAB0000000000000000").unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(matches!(err, CheckpointError::NotACheckpoint(_)));
    }

    #[test]
    fn rejects_version_from_the_future() {
        let dir = TestDir::new("ckpt_future");
        let path = dir.path().join("run.ckpt");
        sample().save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[8..12].copy_from_slice(&99u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(
            matches!(
                err,
                CheckpointError::VersionFromTheFuture {
                    found: 99,
                    supported: SNAPSHOT_VERSION
                }
            ),
            "{err}"
        );
    }

    #[test]
    fn rejects_truncated_file() {
        let dir = TestDir::new("ckpt_trunc");
        let path = dir.path().join("run.ckpt");
        sample().save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // Shorter than the 16-byte header.
        std::fs::write(&path, &bytes[..10]).unwrap();
        assert!(matches!(
            Checkpoint::load(&path).unwrap_err(),
            CheckpointError::Truncated(_)
        ));
        // Header intact, payload cut: lands as a CRC failure.
        std::fs::write(&path, &bytes[..bytes.len() - 3]).unwrap();
        assert!(matches!(
            Checkpoint::load(&path).unwrap_err(),
            CheckpointError::BadChecksum(_)
        ));
    }

    #[test]
    fn loads_v1_format() {
        // Hand-build a v1 file and check it loads with the legacy
        // defaults (no scheduler, no policy state).
        let dir = TestDir::new("ckpt_v1");
        let path = dir.path().join("v1.ckpt");
        let params = [1.0f32, 2.0];
        let velocity = [0.5f32, -0.5];
        let order = [1u64, 0];
        let mut payload = Vec::new();
        payload.extend_from_slice(&3u64.to_le_bytes()); // epoch
        payload.extend_from_slice(&2u64.to_le_bytes()); // d
        for v in params {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        for v in velocity {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        payload.extend_from_slice(&2u64.to_le_bytes()); // n
        for v in order {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        let mut file = Vec::new();
        file.extend_from_slice(MAGIC);
        file.extend_from_slice(&1u32.to_le_bytes());
        file.extend_from_slice(&crc32(&payload).to_le_bytes());
        file.extend_from_slice(&payload);
        std::fs::write(&path, &file).unwrap();
        let c = Checkpoint::load(&path).unwrap();
        assert_eq!(c.epoch, 3);
        assert_eq!(c.params, params);
        assert_eq!(c.velocity, velocity);
        assert_eq!(c.order, order);
        assert_eq!(c.sched, None);
        assert_eq!(c.policy_state, None);
    }

    #[test]
    fn crc32_known_vector() {
        // "123456789" -> 0xCBF43926 (standard check value)
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn manifest_roundtrip_and_fingerprint_gate() {
        let dir = TestDir::new("ckpt_manifest");
        let rd = RunDir::create(dir.path(), manifest()).unwrap();
        let back = RunDir::open(dir.path()).unwrap();
        assert_eq!(back.manifest, rd.manifest);
        back.check_fingerprint(0xDEAD_BEEF).unwrap();
        let err = back.check_fingerprint(0x1234).unwrap_err();
        assert!(matches!(
            err,
            CheckpointError::FingerprintMismatch {
                manifest: 0xDEAD_BEEF,
                config: 0x1234
            }
        ));
    }

    #[test]
    fn open_without_manifest_is_not_a_checkpoint() {
        let dir = TestDir::new("ckpt_no_manifest");
        std::fs::create_dir_all(dir.path()).unwrap();
        assert!(matches!(
            RunDir::open(dir.path()).unwrap_err(),
            CheckpointError::NotACheckpoint(_)
        ));
    }

    #[test]
    fn manifest_from_the_future_is_refused() {
        let dir = TestDir::new("ckpt_manifest_future");
        let rd = RunDir::create(dir.path(), manifest()).unwrap();
        let mpath = rd.path().join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&mpath).unwrap();
        let bumped = text.replace(
            "\"schema_version\":1",
            "\"schema_version\":9",
        );
        assert_ne!(text, bumped, "schema_version key not found");
        std::fs::write(&mpath, bumped).unwrap();
        assert!(matches!(
            RunDir::open(dir.path()).unwrap_err(),
            CheckpointError::VersionFromTheFuture { found: 9, .. }
        ));
    }

    #[test]
    fn truncated_manifest_is_typed() {
        let dir = TestDir::new("ckpt_manifest_trunc");
        let rd = RunDir::create(dir.path(), manifest()).unwrap();
        let mpath = rd.path().join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&mpath).unwrap();
        std::fs::write(&mpath, &text[..text.len() / 2]).unwrap();
        assert!(matches!(
            RunDir::open(dir.path()).unwrap_err(),
            CheckpointError::Malformed(_)
        ));
    }

    #[test]
    fn retention_keeps_last_k_and_missing_epoch_is_typed() {
        let dir = TestDir::new("ckpt_retention");
        let rd = RunDir::create(dir.path(), manifest()).unwrap();
        for e in 0..6u64 {
            let snap = Checkpoint { epoch: e, ..sample() };
            rd.save_epoch(&snap, 3).unwrap();
        }
        assert_eq!(rd.epochs().unwrap(), vec![3, 4, 5]);
        assert_eq!(rd.latest_epoch().unwrap(), Some(5));
        assert_eq!(rd.load_latest().unwrap().unwrap().epoch, 5);
        let err = rd.load_epoch(1).unwrap_err();
        assert!(matches!(
            err,
            CheckpointError::MissingEpoch { epoch: 1, .. }
        ));
    }
}
