//! Checkpointing: binary snapshots of a run (params, momentum, epoch,
//! ordering permutation) with integrity checksums, so long paper-scale
//! runs can resume after interruption.
//!
//! Format (little-endian):
//! ```text
//! magic "GRABCKPT" | u32 version | u32 crc32(payload) | payload
//! payload: u64 epoch | u64 d | f32[d] params | f32[d] velocity
//!        | u64 n | u64[n] order
//! ```

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

const MAGIC: &[u8; 8] = b"GRABCKPT";
const VERSION: u32 = 1;

/// One resumable snapshot.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    /// Epoch the snapshot was taken after.
    pub epoch: u64,
    /// Model parameters (flattened, layout per the artifact manifest).
    pub params: Vec<f32>,
    /// Optimizer momentum buffer, same layout as `params`.
    pub velocity: Vec<f32>,
    /// The ordering policy's next epoch permutation.
    pub order: Vec<u64>,
}

/// CRC-32 (IEEE 802.3, reflected) — implemented in-tree; the vendored dep
/// closure is reserved for the xla crate.
pub fn crc32(data: &[u8]) -> u32 {
    let mut table = [0u32; 256];
    for (i, entry) in table.iter_mut().enumerate() {
        let mut c = i as u32;
        for _ in 0..8 {
            c = if c & 1 != 0 {
                0xEDB8_8320 ^ (c >> 1)
            } else {
                c >> 1
            };
        }
        *entry = c;
    }
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc = table[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    crc ^ 0xFFFF_FFFF
}

impl Checkpoint {
    /// Serialize atomically to `path` (temp file + rename).
    pub fn save(&self, path: &Path) -> Result<()> {
        anyhow::ensure!(self.params.len() == self.velocity.len(),
                        "params/velocity length mismatch");
        let mut payload = Vec::with_capacity(
            16 + self.params.len() * 8 + self.order.len() * 8);
        payload.extend_from_slice(&self.epoch.to_le_bytes());
        payload.extend_from_slice(
            &(self.params.len() as u64).to_le_bytes());
        for v in &self.params {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        for v in &self.velocity {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        payload.extend_from_slice(
            &(self.order.len() as u64).to_le_bytes());
        for v in &self.order {
            payload.extend_from_slice(&v.to_le_bytes());
        }

        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        // Write to a temp file then rename: a crash mid-write never
        // corrupts the previous checkpoint.
        let tmp = path.with_extension("tmp");
        {
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            f.write_all(MAGIC)?;
            f.write_all(&VERSION.to_le_bytes())?;
            f.write_all(&crc32(&payload).to_le_bytes())?;
            f.write_all(&payload)?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Read + verify (magic, version, CRC) a snapshot from `path`.
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut header = [0u8; 16];
        f.read_exact(&mut header)?;
        if &header[0..8] != MAGIC {
            bail!("{} is not a grab checkpoint", path.display());
        }
        let version = u32::from_le_bytes(header[8..12].try_into()?);
        if version != VERSION {
            bail!("unsupported checkpoint version {version}");
        }
        let want_crc = u32::from_le_bytes(header[12..16].try_into()?);
        let mut payload = Vec::new();
        f.read_to_end(&mut payload)?;
        if crc32(&payload) != want_crc {
            bail!("checkpoint {} failed CRC check (corrupt/truncated)",
                  path.display());
        }
        let mut off = 0usize;
        let mut take = |n: usize| -> Result<&[u8]> {
            let s = payload
                .get(off..off + n)
                .ok_or_else(|| anyhow::anyhow!("truncated payload"))?;
            off += n;
            Ok(s)
        };
        let epoch = u64::from_le_bytes(take(8)?.try_into()?);
        let d = u64::from_le_bytes(take(8)?.try_into()?) as usize;
        let mut params = Vec::with_capacity(d);
        for _ in 0..d {
            params.push(f32::from_le_bytes(take(4)?.try_into()?));
        }
        let mut velocity = Vec::with_capacity(d);
        for _ in 0..d {
            velocity.push(f32::from_le_bytes(take(4)?.try_into()?));
        }
        let n = u64::from_le_bytes(take(8)?.try_into()?) as usize;
        let mut order = Vec::with_capacity(n);
        for _ in 0..n {
            order.push(u64::from_le_bytes(take(8)?.try_into()?));
        }
        if off != payload.len() {
            bail!("trailing bytes in checkpoint");
        }
        Ok(Checkpoint { epoch, params, velocity, order })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            epoch: 7,
            params: vec![1.5, -2.25, 0.0, 3.75],
            velocity: vec![0.1, 0.2, -0.3, 0.4],
            order: vec![3, 1, 0, 2],
        }
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("grab_ckpt_test");
        let path = dir.join("run.ckpt");
        let c = sample();
        c.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(c, back);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn detects_corruption() {
        let dir = std::env::temp_dir().join("grab_ckpt_corrupt");
        let path = dir.join("run.ckpt");
        sample().save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(err.to_string().contains("CRC"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rejects_wrong_magic() {
        let dir = std::env::temp_dir().join("grab_ckpt_magic");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"NOTAGRAB0000000000000000").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn crc32_known_vector() {
        // "123456789" -> 0xCBF43926 (standard check value)
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }
}
