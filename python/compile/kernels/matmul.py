"""Tiled matmul Pallas kernel — the dense forward hot-spot (logreg / heads).

Classic MXU-oriented tiling: grid (M/bm, N/bn, K/bk); each step multiplies a
(bm, bk) x (bk, bn) tile pair and accumulates into the f32 output tile that
stays resident in VMEM across the K dimension (revisited-block pattern:
out index_map ignores k). On a real TPU bm=bn=bk=128 feeds the 128x128
systolic array at full occupancy in bf16; here we lower interpret=True for
the CPU PJRT plugin and keep the same schedule so the HLO structure matches
what the Mosaic path would pipeline.

Inputs of arbitrary (M, K, N) are padded up to tile multiples and the result
is sliced back, so callers never have to think about alignment.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_M = 32
TILE_N = 32
TILE_K = 128


def _matmul_kernel(x_ref, w_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _pad2(a: jnp.ndarray, bm: int, bn: int) -> jnp.ndarray:
    m, n = a.shape
    return jnp.pad(a, ((0, (-m) % bm), (0, (-n) % bn)))


def matmul(x: jnp.ndarray, w: jnp.ndarray, *,
           tm: int = TILE_M, tn: int = TILE_N, tk: int = TILE_K,
           interpret: bool = True) -> jnp.ndarray:
    """Compute x @ w with a tiled Pallas kernel. x: (M, K), w: (K, N)."""
    assert x.ndim == 2 and w.ndim == 2 and x.shape[1] == w.shape[0], (
        x.shape, w.shape)
    m, k = x.shape
    _, n = w.shape
    xp = _pad2(x.astype(jnp.float32), tm, tk)
    wp = _pad2(w.astype(jnp.float32), tk, tn)
    gm, gk = xp.shape[0] // tm, xp.shape[1] // tk
    gn = wp.shape[1] // tn

    out = pl.pallas_call(
        _matmul_kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], wp.shape[1]),
                                       jnp.float32),
        interpret=interpret,
    )(xp, wp)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("tm", "tn", "tk"))
def matmul_jit(x, w, tm: int = TILE_M, tn: int = TILE_N, tk: int = TILE_K):
    return matmul(x, w, tm=tm, tn=tn, tk=tk)
