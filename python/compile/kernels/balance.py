"""GraB balance step (Algorithm 5 inner loop) as a Pallas kernel.

This is the per-example hot-spot of online Gradient Balancing: given the
signed running sum `s`, the stale mean `m` and the fresh per-example gradient
`g`, compute the centered gradient c = g - m, decide the sign

    eps = +1  iff  ||s + c||_2 < ||s - c||_2   (<=>  <s, c> < 0)

and apply the signed update s' = s + eps * c. Fusing center + decide + update
into one kernel means `g` is read from HBM exactly once.

TPU mapping (see DESIGN.md §Hardware-Adaptation): `d` is tiled into
VMEM-resident blocks; the decision scalar <s, c> is accumulated across grid
steps in a VMEM scratch accumulator; the final grid step materializes eps and
the signed update is applied blockwise on a second pass over the same
VMEM-resident tiles. On CPU we lower with interpret=True (Mosaic custom-calls
cannot run on the CPU PJRT plugin); correctness is checked against
kernels.ref.ref_balance_step.

The norm-invariant form (only the *sign* of <s,c> matters) is exactly why the
paper recommends Algorithm 5 over Algorithm 6 in practice: no normalizer for
||z_i|| <= 1 has to be estimated.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block size along d. 2048 f32 = 8 KiB per operand tile; with 3 inputs + 2
# vector outputs resident that is ~40 KiB of VMEM per step, far under the
# ~16 MiB VMEM budget — chosen small so the grid exercises multi-step
# accumulation even for the d=7850 logreg model.
BLOCK_D = 2048


def _pad_to_block(v: jnp.ndarray, block: int) -> jnp.ndarray:
    d = v.shape[0]
    rem = (-d) % block
    if rem == 0:
        return v
    return jnp.pad(v, (0, rem))


def _dot_kernel(s_ref, c_ref, acc_ref):
    """Grid step i: accumulate the partial <s, c> for this d-block."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.sum(s_ref[...] * c_ref[...])[None]


def _update_kernel(eps_ref, s_ref, c_ref, out_ref):
    """Grid step i: apply the signed update for this d-block."""
    out_ref[...] = s_ref[...] + eps_ref[0] * c_ref[...]


def balance_step(s: jnp.ndarray, m: jnp.ndarray, g: jnp.ndarray,
                 *, block_d: int = BLOCK_D, interpret: bool = True):
    """Fused GraB balance step.

    Args:
      s: f32[d] signed running sum.
      m: f32[d] stale mean of the previous epoch's gradients.
      g: f32[d] fresh per-example gradient.

    Returns:
      (eps: f32[] in {+1,-1}, s_new: f32[d], c: f32[d]).
    """
    d = s.shape[0]
    c = g.astype(jnp.float32) - m.astype(jnp.float32)

    sp = _pad_to_block(s.astype(jnp.float32), block_d)
    cp = _pad_to_block(c, block_d)
    nblk = sp.shape[0] // block_d

    dot = pl.pallas_call(
        _dot_kernel,
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((block_d,), lambda i: (i,)),
            pl.BlockSpec((block_d,), lambda i: (i,)),
        ],
        # Single-element accumulator revisited by every grid step.
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        interpret=interpret,
    )(sp, cp)[0]

    eps = jnp.where(dot < 0.0, 1.0, -1.0).astype(jnp.float32)

    s_new = pl.pallas_call(
        _update_kernel,
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block_d,), lambda i: (i,)),
            pl.BlockSpec((block_d,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_d,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(sp.shape, jnp.float32),
        interpret=interpret,
    )(eps[None], sp, cp)[:d]

    return eps, s_new, c


@functools.partial(jax.jit, static_argnames=("block_d",))
def balance_step_jit(s, m, g, block_d: int = BLOCK_D):
    return balance_step(s, m, g, block_d=block_d)
