"""Fused momentum-SGD update as a Pallas kernel.

One pass over (params, velocity, grad) per optimizer step:

    v' = mu * v + (g + wd * p)
    p' = p - lr * v'

Fusing the three reads + two writes into a single blockwise kernel keeps the
optimizer memory-bound at exactly one round trip per tensor — the same
argument a CUDA fused optimizer makes. On TPU the d axis is tiled into VMEM
blocks (BlockSpec below); hyperparameters travel as a tiny (3,) vector so
one compiled artifact serves every (lr, mu, wd) without recompilation.

Lowered interpret=True for the CPU PJRT plugin; oracle in ref.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_D = 2048


def _sgd_kernel(hyper_ref, p_ref, v_ref, g_ref, p_out_ref, v_out_ref):
    lr = hyper_ref[0]
    mu = hyper_ref[1]
    wd = hyper_ref[2]
    g = g_ref[...] + wd * p_ref[...]
    v_new = mu * v_ref[...] + g
    v_out_ref[...] = v_new
    p_out_ref[...] = p_ref[...] - lr * v_new


def sgd_step(p: jnp.ndarray, v: jnp.ndarray, g: jnp.ndarray,
             hyper: jnp.ndarray, *, block_d: int = BLOCK_D,
             interpret: bool = True):
    """Fused momentum-SGD step.

    Args:
      p, v, g: f32[d] params / velocity / gradient.
      hyper: f32[3] = (lr, momentum, weight_decay).

    Returns:
      (p_new, v_new): f32[d] each.
    """
    d = p.shape[0]
    pad = (-d) % block_d
    pp = jnp.pad(p.astype(jnp.float32), (0, pad))
    vp = jnp.pad(v.astype(jnp.float32), (0, pad))
    gp = jnp.pad(g.astype(jnp.float32), (0, pad))
    nblk = pp.shape[0] // block_d

    p_new, v_new = pl.pallas_call(
        _sgd_kernel,
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((3,), lambda i: (0,)),
            pl.BlockSpec((block_d,), lambda i: (i,)),
            pl.BlockSpec((block_d,), lambda i: (i,)),
            pl.BlockSpec((block_d,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_d,), lambda i: (i,)),
            pl.BlockSpec((block_d,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(pp.shape, jnp.float32),
            jax.ShapeDtypeStruct(pp.shape, jnp.float32),
        ],
        interpret=interpret,
    )(hyper.astype(jnp.float32), pp, vp, gp)
    return p_new[:d], v_new[:d]


@functools.partial(jax.jit, static_argnames=("block_d",))
def sgd_step_jit(p, v, g, hyper, block_d: int = BLOCK_D):
    return sgd_step(p, v, g, hyper, block_d=block_d)
