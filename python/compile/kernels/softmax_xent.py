"""Fused softmax cross-entropy (loss + dlogits) Pallas kernel.

Per-example losses are what GraB orders on, so the loss kernel emits the
per-example vector, not a scalar mean. Fusing loss and gradient-of-logits
into a single kernel reads the logits tile from HBM once and writes both
outputs from the same VMEM-resident exponentials — the fusion a CUDA
implementation would express with a shared-memory row reduction.

Row-blocked: each grid step owns a (BLOCK_B, C) tile; C (the class count)
stays un-tiled because every model here has C <= 64, far below a VMEM lane
tile. Labels arrive as int32 indices and are one-hotted in-kernel via
broadcasted_iota, avoiding a gather.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_B = 64


def _xent_kernel(logits_ref, labels_ref, loss_ref, dlogits_ref):
    logits = logits_ref[...]
    labels = labels_ref[...]
    c = logits.shape[-1]

    m = jnp.max(logits, axis=-1, keepdims=True)
    z = logits - m
    e = jnp.exp(z)
    se = jnp.sum(e, axis=-1, keepdims=True)
    log_probs = z - jnp.log(se)

    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, dimension=1)
    onehot = (iota == labels[:, None]).astype(jnp.float32)

    loss_ref[...] = -jnp.sum(log_probs * onehot, axis=-1)
    dlogits_ref[...] = e / se - onehot
    del c


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray, *,
                 block_b: int = BLOCK_B, interpret: bool = True):
    """Fused per-example CE loss and dlogits.

    Args:
      logits: f32[B, C]
      labels: i32[B]

    Returns:
      (loss: f32[B], dlogits: f32[B, C])
    """
    b, c = logits.shape
    pad = (-b) % block_b
    lp = jnp.pad(logits.astype(jnp.float32), ((0, pad), (0, 0)))
    # Padded rows get label 0; their outputs are sliced away below.
    yp = jnp.pad(labels.astype(jnp.int32), (0, pad))
    gb = lp.shape[0] // block_b

    loss, dlogits = pl.pallas_call(
        _xent_kernel,
        grid=(gb,),
        in_specs=[
            pl.BlockSpec((block_b, c), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b, c), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((lp.shape[0],), jnp.float32),
            jax.ShapeDtypeStruct(lp.shape, jnp.float32),
        ],
        interpret=interpret,
    )(lp, yp)
    return loss[:b], dlogits[:b]


@functools.partial(jax.jit, static_argnames=("block_b",))
def softmax_xent_jit(logits, labels, block_b: int = BLOCK_B):
    return softmax_xent(logits, labels, block_b=block_b)
