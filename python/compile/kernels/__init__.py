"""L1 Pallas kernels for the GraB stack (build-time only, interpret=True)."""
from . import balance, matmul, ref, sgd, softmax_xent  # noqa: F401
