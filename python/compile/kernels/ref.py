"""Pure-jnp oracles for the Pallas kernels (L1 correctness references).

Every Pallas kernel in this package has a reference implementation here,
written with plain jax.numpy only. pytest (python/tests/test_kernels.py)
sweeps shapes/dtypes with hypothesis and asserts allclose between the kernel
(interpret=True) and these oracles.
"""

from __future__ import annotations

import jax.numpy as jnp


def ref_matmul(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Reference for kernels.matmul.matmul: plain (M,K)@(K,N) in f32."""
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


def ref_softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray):
    """Reference for kernels.softmax_xent.softmax_xent.

    Args:
      logits: f32[B, C]
      labels: i32[B] class indices in [0, C)

    Returns:
      (loss[B], dlogits[B, C]) — per-example cross-entropy and its gradient
      with respect to logits.
    """
    logits = logits.astype(jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    z = logits - m
    e = jnp.exp(z)
    se = jnp.sum(e, axis=-1, keepdims=True)
    log_probs = z - jnp.log(se)
    b = logits.shape[0]
    loss = -log_probs[jnp.arange(b), labels]
    onehot = jnp.zeros_like(logits).at[jnp.arange(b), labels].set(1.0)
    dlogits = e / se - onehot
    return loss, dlogits


def ref_balance_step(s: jnp.ndarray, m: jnp.ndarray, g: jnp.ndarray):
    """Reference for kernels.balance.balance_step (GraB Algorithm 5 inner step).

    c = g - m (stale-mean centering); epsilon = +1 iff ||s+c|| < ||s-c||,
    which is equivalent to <s, c> < 0 (the norm-invariant test of Alg. 5);
    s_new = s + epsilon * c.

    Returns (epsilon: f32[], s_new: f32[d], c: f32[d]).
    """
    s = s.astype(jnp.float32)
    c = g.astype(jnp.float32) - m.astype(jnp.float32)
    dot = jnp.vdot(s, c)
    eps = jnp.where(dot < 0.0, 1.0, -1.0).astype(jnp.float32)
    return eps, s + eps * c, c


def ref_sgd_step(p: jnp.ndarray, v: jnp.ndarray, g: jnp.ndarray,
                 hyper: jnp.ndarray):
    """Reference for kernels.sgd.sgd_step (PyTorch-style coupled decay)."""
    lr, mu, wd = hyper[0], hyper[1], hyper[2]
    g2 = g.astype(jnp.float32) + wd * p.astype(jnp.float32)
    v_new = mu * v.astype(jnp.float32) + g2
    return p - lr * v_new, v_new
