"""L2 — JAX model zoo for the GraB reproduction (build-time only).

Four models mirroring the paper's Section 6 workloads:

  * ``logreg``      — logistic regression (MNIST-like task, Fig. 2a). The
    forward matmul and the fused softmax-CE use the L1 Pallas kernels, and
    per-example gradients are computed in *closed form* from the kernel's
    dlogits output, so the Pallas kernels sit on the gradient hot path of
    the exported HLO.
  * ``lenet``       — LeNet-5-style CNN (CIFAR-like task, Fig. 2b).
  * ``lstm``        — single-layer LSTM LM (WikiText-2-like task, Fig. 2c).
  * ``transformer`` — 2-layer tiny transformer classifier (~100k params,
    GLUE-like task, Fig. 2d and the end-to-end driver).

Every model exposes the same contract, consumed by aot.py:

  param_specs() -> [(name, shape)]        fixed flat-vector layout
  init(seed) -> np.float32[d]             deterministic init
  per_example(params, X, Y) -> (losses[B], grads[B, d])
  evaluate(params, X, Y) -> (loss_sum[], correct[])

Per-example gradients are exactly what GraB needs (paper §"On the granularity
of example ordering" recommends JAX's vmap-of-grad; that is literally what we
export). The rust coordinator (L3) treats `grads[B, d]` as B ordering units
and accumulates them for the optimizer step (the paper's gradient-
accumulation workaround, Listing 1).
"""

from __future__ import annotations

import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import matmul as kmatmul
from .kernels import softmax_xent as kxent

Spec = List[Tuple[str, Tuple[int, ...]]]


# ---------------------------------------------------------------------------
# flat <-> pytree plumbing
# ---------------------------------------------------------------------------

def spec_size(specs: Spec) -> int:
    return sum(int(np.prod(s)) for _, s in specs)


def unflatten(flat: jnp.ndarray, specs: Spec) -> Dict[str, jnp.ndarray]:
    out, off = {}, 0
    for name, shape in specs:
        n = int(np.prod(shape))
        out[name] = flat[off:off + n].reshape(shape)
        off += n
    return out


def flatten_np(params: Dict[str, np.ndarray], specs: Spec) -> np.ndarray:
    return np.concatenate(
        [np.asarray(params[name], np.float32).reshape(-1)
         for name, _ in specs])


def _uniform(rng: np.random.Generator, shape, scale) -> np.ndarray:
    return rng.uniform(-scale, scale, size=shape).astype(np.float32)


# ---------------------------------------------------------------------------
# logreg — MNIST-like (Fig. 2a). d = 784*10 + 10 = 7850, matching the paper.
# ---------------------------------------------------------------------------

class LogReg:
    name = "logreg"
    in_dim = 784
    n_classes = 10

    @classmethod
    def param_specs(cls) -> Spec:
        return [("w", (cls.in_dim, cls.n_classes)), ("b", (cls.n_classes,))]

    @classmethod
    def init(cls, seed: int = 0) -> np.ndarray:
        rng = np.random.default_rng(seed)
        scale = 1.0 / math.sqrt(cls.in_dim)
        return flatten_np(
            {"w": _uniform(rng, (cls.in_dim, cls.n_classes), scale),
             "b": np.zeros((cls.n_classes,), np.float32)},
            cls.param_specs())

    # Closed-form batched per-example grads: both Pallas kernels on the path.
    @classmethod
    def per_example(cls, flat, X, Y):
        p = unflatten(flat, cls.param_specs())
        logits = kmatmul.matmul(X, p["w"]) + p["b"][None, :]
        losses, dlogits = kxent.softmax_xent(logits, Y)
        # grad_w[b] = outer(x_b, dlogits_b); grad_b[b] = dlogits_b
        gw = X[:, :, None] * dlogits[:, None, :]            # [B, in, C]
        grads = jnp.concatenate(
            [gw.reshape(X.shape[0], -1), dlogits], axis=1)  # [B, d]
        return losses, grads

    @classmethod
    def evaluate(cls, flat, X, Y):
        p = unflatten(flat, cls.param_specs())
        logits = kmatmul.matmul(X, p["w"]) + p["b"][None, :]
        losses, _ = kxent.softmax_xent(logits, Y)
        correct = jnp.sum((jnp.argmax(logits, axis=-1) == Y)
                          .astype(jnp.float32))
        return jnp.sum(losses), correct


# ---------------------------------------------------------------------------
# Generic autodiff path shared by the non-convex models
# ---------------------------------------------------------------------------

def _autodiff_per_example(loss_fn, flat, X, Y):
    def one(x, y):
        return loss_fn(flat, x, y)

    losses = jax.vmap(one)(X, Y)
    grads = jax.vmap(jax.grad(lambda f, x, y: loss_fn(f, x, y)),
                     in_axes=(None, 0, 0))(flat, X, Y)
    return losses, grads


# ---------------------------------------------------------------------------
# lenet — CIFAR-like (Fig. 2b). LeNet-5 shape on 3x32x32 inputs.
# ---------------------------------------------------------------------------

class LeNet:
    name = "lenet"
    in_dim = 3 * 32 * 32
    n_classes = 10

    @classmethod
    def param_specs(cls) -> Spec:
        return [
            ("c1w", (6, 3, 5, 5)), ("c1b", (6,)),
            ("c2w", (16, 6, 5, 5)), ("c2b", (16,)),
            ("f1w", (400, 120)), ("f1b", (120,)),
            ("f2w", (120, 84)), ("f2b", (84,)),
            ("f3w", (84, 10)), ("f3b", (10,)),
        ]

    @classmethod
    def init(cls, seed: int = 0) -> np.ndarray:
        rng = np.random.default_rng(seed + 1)
        p = {}
        for name, shape in cls.param_specs():
            if name.endswith("b"):
                p[name] = np.zeros(shape, np.float32)
            else:
                fan_in = int(np.prod(shape[1:])) if len(shape) == 4 \
                    else shape[0]
                p[name] = _uniform(rng, shape, 1.0 / math.sqrt(fan_in))
        return flatten_np(p, cls.param_specs())

    @classmethod
    def _forward(cls, p, x):
        img = x.reshape(1, 3, 32, 32)
        h = jax.lax.conv_general_dilated(
            img, p["c1w"], (1, 1), "VALID")  # [1, 6, 28, 28]
        h = jax.nn.relu(h + p["c1b"][None, :, None, None])
        h = jax.lax.reduce_window(
            h, 0.0, jax.lax.add, (1, 1, 2, 2), (1, 1, 2, 2), "VALID") / 4.0
        h = jax.lax.conv_general_dilated(
            h, p["c2w"], (1, 1), "VALID")    # [1, 16, 10, 10]
        h = jax.nn.relu(h + p["c2b"][None, :, None, None])
        h = jax.lax.reduce_window(
            h, 0.0, jax.lax.add, (1, 1, 2, 2), (1, 1, 2, 2), "VALID") / 4.0
        h = h.reshape(-1)                    # 16*5*5 = 400
        h = jax.nn.relu(h @ p["f1w"] + p["f1b"])
        h = jax.nn.relu(h @ p["f2w"] + p["f2b"])
        return h @ p["f3w"] + p["f3b"]

    @classmethod
    def _loss(cls, flat, x, y):
        p = unflatten(flat, cls.param_specs())
        logits = cls._forward(p, x)
        logz = jax.nn.logsumexp(logits)
        return logz - logits[y]

    @classmethod
    def per_example(cls, flat, X, Y):
        return _autodiff_per_example(cls._loss, flat, X, Y)

    @classmethod
    def evaluate(cls, flat, X, Y):
        p = unflatten(flat, cls.param_specs())

        def one(x):
            return cls._forward(p, x)

        logits = jax.vmap(one)(X)
        logz = jax.nn.logsumexp(logits, axis=-1)
        losses = logz - logits[jnp.arange(X.shape[0]), Y]
        correct = jnp.sum((jnp.argmax(logits, axis=-1) == Y)
                          .astype(jnp.float32))
        return jnp.sum(losses), correct


# ---------------------------------------------------------------------------
# lstm — WikiText-2-like character LM (Fig. 2c). One ordering unit = one
# bptt-length sequence, like the paper's batch-of-sequences granularity.
# ---------------------------------------------------------------------------

class LstmLM:
    name = "lstm"
    vocab = 32
    embed = 32
    hidden = 64
    bptt = 35

    @classmethod
    def param_specs(cls) -> Spec:
        v, e, h = cls.vocab, cls.embed, cls.hidden
        return [
            ("emb", (v, e)),
            ("wx", (e, 4 * h)), ("wh", (h, 4 * h)), ("bi", (4 * h,)),
            ("ow", (h, v)), ("ob", (v,)),
        ]

    @classmethod
    def init(cls, seed: int = 0) -> np.ndarray:
        rng = np.random.default_rng(seed + 2)
        p = {}
        for name, shape in cls.param_specs():
            if name in ("bi", "ob"):
                p[name] = np.zeros(shape, np.float32)
            else:
                p[name] = _uniform(rng, shape, 1.0 / math.sqrt(shape[0]))
        return flatten_np(p, cls.param_specs())

    @classmethod
    def _logits(cls, p, x):
        """x: i32[T] -> logits f32[T, vocab]."""
        h = cls.hidden
        emb = p["emb"][x]  # [T, E]

        def step(carry, e_t):
            hprev, cprev = carry
            z = e_t @ p["wx"] + hprev @ p["wh"] + p["bi"]
            i, f, g, o = jnp.split(z, 4)
            i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f), jax.nn.sigmoid(o)
            g = jnp.tanh(g)
            c = f * cprev + i * g
            hh = o * jnp.tanh(c)
            return (hh, c), hh

        (_, _), hs = jax.lax.scan(
            step, (jnp.zeros(h), jnp.zeros(h)), emb)
        return hs @ p["ow"] + p["ob"]  # [T, V]

    @classmethod
    def _loss(cls, flat, x, y):
        p = unflatten(flat, cls.param_specs())
        logits = cls._logits(p, x)
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = logits[jnp.arange(cls.bptt), y]
        return jnp.mean(logz - ll)

    @classmethod
    def per_example(cls, flat, X, Y):
        return _autodiff_per_example(cls._loss, flat, X, Y)

    @classmethod
    def evaluate(cls, flat, X, Y):
        p = unflatten(flat, cls.param_specs())

        def one(x):
            return cls._logits(p, x)

        logits = jax.vmap(one)(X)  # [B, T, V]
        logz = jax.nn.logsumexp(logits, axis=-1)
        b, t = Y.shape
        ll = jnp.take_along_axis(
            logits, Y[:, :, None], axis=-1).squeeze(-1)
        losses = jnp.mean(logz - ll, axis=-1)
        correct = jnp.sum((jnp.argmax(logits, axis=-1) == Y)
                          .astype(jnp.float32)) / t
        del b
        return jnp.sum(losses), correct


# ---------------------------------------------------------------------------
# transformer — GLUE-like classifier (Fig. 2d / end-to-end driver). 2 layers,
# 2 heads, hidden 64 -> ~105k params (BERT-Tiny stand-in at this testbed's
# scale; the regime where Greedy Ordering's O(nd) state explodes).
# ---------------------------------------------------------------------------

class TinyTransformer:
    name = "transformer"
    vocab = 64
    seq = 32
    dim = 64
    heads = 2
    ffn = 128
    layers = 2
    n_classes = 2

    @classmethod
    def param_specs(cls) -> Spec:
        d, f = cls.dim, cls.ffn
        specs: Spec = [("emb", (cls.vocab, d)), ("pos", (cls.seq, d))]
        for i in range(cls.layers):
            specs += [
                (f"l{i}.qkv", (d, 3 * d)), (f"l{i}.qkvb", (3 * d,)),
                (f"l{i}.proj", (d, d)), (f"l{i}.projb", (d,)),
                (f"l{i}.ln1g", (d,)), (f"l{i}.ln1b", (d,)),
                (f"l{i}.ff1", (d, f)), (f"l{i}.ff1b", (f,)),
                (f"l{i}.ff2", (f, d)), (f"l{i}.ff2b", (d,)),
                (f"l{i}.ln2g", (d,)), (f"l{i}.ln2b", (d,)),
            ]
        specs += [("head", (d, cls.n_classes)), ("headb", (cls.n_classes,))]
        return specs

    @classmethod
    def init(cls, seed: int = 0) -> np.ndarray:
        rng = np.random.default_rng(seed + 3)
        p = {}
        for name, shape in cls.param_specs():
            if name.endswith("g"):           # layernorm gains
                p[name] = np.ones(shape, np.float32)
            elif name.endswith("b"):         # biases & layernorm shifts
                p[name] = np.zeros(shape, np.float32)
            else:
                p[name] = _uniform(rng, shape, 1.0 / math.sqrt(shape[0]))
        return flatten_np(p, cls.param_specs())

    @staticmethod
    def _ln(x, g, b):
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
        return (x - mu) / jnp.sqrt(var + 1e-5) * g + b

    @classmethod
    def _forward(cls, p, x):
        """x: i32[T] -> logits f32[n_classes]."""
        d, nh = cls.dim, cls.heads
        hd = d // nh
        h = p["emb"][x] + p["pos"]  # [T, D]
        t = h.shape[0]
        for i in range(cls.layers):
            hn = cls._ln(h, p[f"l{i}.ln1g"], p[f"l{i}.ln1b"])
            qkv = hn @ p[f"l{i}.qkv"] + p[f"l{i}.qkvb"]
            q, k, v = jnp.split(qkv, 3, axis=-1)
            q = q.reshape(t, nh, hd).transpose(1, 0, 2)
            k = k.reshape(t, nh, hd).transpose(1, 0, 2)
            v = v.reshape(t, nh, hd).transpose(1, 0, 2)
            att = jnp.einsum("hqd,hkd->hqk", q, k) / math.sqrt(hd)
            att = jax.nn.softmax(att, axis=-1)
            o = jnp.einsum("hqk,hkd->hqd", att, v)
            o = o.transpose(1, 0, 2).reshape(t, d)
            h = h + o @ p[f"l{i}.proj"] + p[f"l{i}.projb"]
            hn = cls._ln(h, p[f"l{i}.ln2g"], p[f"l{i}.ln2b"])
            ff = jax.nn.relu(hn @ p[f"l{i}.ff1"] + p[f"l{i}.ff1b"])
            h = h + ff @ p[f"l{i}.ff2"] + p[f"l{i}.ff2b"]
        pooled = jnp.mean(h, axis=0)
        return pooled @ p["head"] + p["headb"]

    @classmethod
    def _loss(cls, flat, x, y):
        p = unflatten(flat, cls.param_specs())
        logits = cls._forward(p, x)
        return jax.nn.logsumexp(logits) - logits[y]

    @classmethod
    def per_example(cls, flat, X, Y):
        return _autodiff_per_example(cls._loss, flat, X, Y)

    @classmethod
    def evaluate(cls, flat, X, Y):
        p = unflatten(flat, cls.param_specs())

        def one(x):
            return cls._forward(p, x)

        logits = jax.vmap(one)(X)
        logz = jax.nn.logsumexp(logits, axis=-1)
        losses = logz - logits[jnp.arange(X.shape[0]), Y]
        correct = jnp.sum((jnp.argmax(logits, axis=-1) == Y)
                          .astype(jnp.float32))
        return jnp.sum(losses), correct


MODELS = {m.name: m for m in (LogReg, LeNet, LstmLM, TinyTransformer)}


def model_dim(model) -> int:
    return spec_size(model.param_specs())
