"""AOT lowering: JAX (L2, calling L1 Pallas kernels) -> HLO text artifacts.

This is the only place Python touches the system. ``make artifacts`` runs it
once; the rust coordinator (L3) then loads ``artifacts/*.hlo.txt`` through the
PJRT C API and Python never appears on the request path again.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which the published xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts emitted (per model M in {logreg, lenet, lstm, transformer}):

  M_grad.hlo.txt   (params[d], X[B,...], Y[B,...]) -> (losses[B], grads[B,d])
  M_eval.hlo.txt   (params[d], X[E,...], Y[E,...]) -> (loss_sum, correct)

plus the GraB balance step (L1 Pallas kernel) at the dimensions rust uses:

  balance_<d>.hlo.txt  (s[d], m[d], g[d]) -> (eps, s_new[d], c[d])

and ``manifest.json`` describing every artifact's I/O shapes, dtypes and the
flat parameter layout, which rust parses at startup (model registry).
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import balance as kbalance
from .kernels import sgd as ksgd

# Per-model microbatch (grad) and eval-batch sizes. B is the number of
# ordering units handed to GraB per PJRT call; rust accumulates GCC
# microbatches per optimizer step (the paper's gradient-accumulation recipe).
BATCH = {"logreg": 64, "lenet": 16, "lstm": 8, "transformer": 8}
EVAL_BATCH = {"logreg": 256, "lenet": 64, "lstm": 32, "transformer": 64}

# Balance-artifact dimensions: logreg's d (the paper's MNIST model) plus a
# generic power-of-two used by benches/balance_hot.rs.
BALANCE_DIMS = (1024, 7850)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def data_specs(model) -> Tuple[tuple, str, tuple, str]:
    """(x_shape_per_example, x_dtype, y_shape_per_example, y_dtype)."""
    if model.name == "logreg":
        return ((model.in_dim,), "f32", (), "i32")
    if model.name == "lenet":
        return ((model.in_dim,), "f32", (), "i32")
    if model.name == "lstm":
        return ((model.bptt,), "i32", (model.bptt,), "i32")
    if model.name == "transformer":
        return ((model.seq,), "i32", (), "i32")
    raise ValueError(model.name)


def _shape_struct(shape, dtype):
    return jax.ShapeDtypeStruct(
        shape, jnp.float32 if dtype == "f32" else jnp.int32)


def lower_model(model, out_dir: str) -> dict:
    d = M.model_dim(model)
    b, e = BATCH[model.name], EVAL_BATCH[model.name]
    xs, xdt, ys, ydt = data_specs(model)

    params = _shape_struct((d,), "f32")
    gx = _shape_struct((b,) + xs, xdt)
    gy = _shape_struct((b,) + ys, ydt)
    ex = _shape_struct((e,) + xs, xdt)
    ey = _shape_struct((e,) + ys, ydt)

    def grad_fn(p, x, y):
        losses, grads = model.per_example(p, x, y)
        return (losses, grads)

    def eval_fn(p, x, y):
        loss_sum, correct = model.evaluate(p, x, y)
        return (loss_sum, correct)

    grad_path = os.path.join(out_dir, f"{model.name}_grad.hlo.txt")
    eval_path = os.path.join(out_dir, f"{model.name}_eval.hlo.txt")
    with open(grad_path, "w") as f:
        f.write(to_hlo_text(jax.jit(grad_fn).lower(params, gx, gy)))
    with open(eval_path, "w") as f:
        f.write(to_hlo_text(jax.jit(eval_fn).lower(params, ex, ey)))

    layout, off = [], 0
    for name, shape in model.param_specs():
        n = int(np.prod(shape))
        layout.append({"name": name, "shape": list(shape),
                       "offset": off, "size": n})
        off += n

    init = model.init(seed=0)
    init_path = os.path.join(out_dir, f"{model.name}_init.f32")
    init.astype("<f4").tofile(init_path)

    return {
        "name": model.name,
        "dim": d,
        "batch": b,
        "eval_batch": e,
        "x_shape": list(xs),
        "x_dtype": xdt,
        "y_shape": list(ys),
        "y_dtype": ydt,
        "n_classes": getattr(model, "n_classes", 0),
        "vocab": getattr(model, "vocab", 0),
        "grad_hlo": os.path.basename(grad_path),
        "eval_hlo": os.path.basename(eval_path),
        "init_params": os.path.basename(init_path),
        "param_layout": layout,
    }


def lower_sgd(d: int, out_dir: str) -> dict:
    """Fused momentum-SGD optimizer artifact at dimension d."""
    s = _shape_struct((d,), "f32")
    h = _shape_struct((3,), "f32")

    def fn(p, v, g, hyper):
        p_new, v_new = ksgd.sgd_step(p, v, g, hyper)
        return (p_new, v_new)

    path = os.path.join(out_dir, f"sgd_{d}.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(jax.jit(fn).lower(s, s, s, h)))
    return {"dim": d, "hlo": os.path.basename(path)}


def lower_balance(d: int, out_dir: str) -> dict:
    s = _shape_struct((d,), "f32")

    def fn(sv, mv, gv):
        eps, s_new, c = kbalance.balance_step(sv, mv, gv)
        return (eps, s_new, c)

    path = os.path.join(out_dir, f"balance_{d}.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(jax.jit(fn).lower(s, s, s)))
    return {"dim": d, "hlo": os.path.basename(path)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts",
                    help="output directory for HLO artifacts")
    ap.add_argument("--models", default="all",
                    help="comma-separated subset, or 'all'")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    names = (list(M.MODELS) if args.models == "all"
             else args.models.split(","))
    manifest = {"format": 1, "models": [], "balance": [], "sgd": []}
    for name in names:
        model = M.MODELS[name]
        print(f"[aot] lowering {name} (d={M.model_dim(model)}) ...",
              flush=True)
        manifest["models"].append(lower_model(model, args.out))
    for d in BALANCE_DIMS:
        print(f"[aot] lowering balance_{d} ...", flush=True)
        manifest["balance"].append(lower_balance(d, args.out))
    for d in BALANCE_DIMS:
        print(f"[aot] lowering sgd_{d} ...", flush=True)
        manifest["sgd"].append(lower_sgd(d, args.out))

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] wrote manifest with {len(manifest['models'])} models, "
          f"{len(manifest['balance'])} balance kernels to {args.out}")


if __name__ == "__main__":
    main()
