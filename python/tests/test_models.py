"""L2 model correctness: shapes, closed-form vs autodiff grads, trainability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


def _fake_batch(model, b, seed=0):
    rng = np.random.default_rng(seed)
    if model.name in ("logreg", "lenet"):
        x = jnp.asarray(rng.normal(size=(b, model.in_dim)), jnp.float32)
        y = jnp.asarray(rng.integers(0, model.n_classes, size=b), jnp.int32)
    elif model.name == "lstm":
        x = jnp.asarray(rng.integers(0, model.vocab, size=(b, model.bptt)),
                        jnp.int32)
        y = jnp.asarray(rng.integers(0, model.vocab, size=(b, model.bptt)),
                        jnp.int32)
    else:  # transformer
        x = jnp.asarray(rng.integers(0, model.vocab, size=(b, model.seq)),
                        jnp.int32)
        y = jnp.asarray(rng.integers(0, model.n_classes, size=b), jnp.int32)
    return x, y


@pytest.mark.parametrize("name", list(M.MODELS))
def test_shapes_and_determinism(name):
    model = M.MODELS[name]
    d = M.model_dim(model)
    p1, p2 = model.init(seed=0), model.init(seed=0)
    np.testing.assert_array_equal(p1, p2)
    assert p1.shape == (d,)
    x, y = _fake_batch(model, 4)
    losses, grads = model.per_example(jnp.asarray(p1), x, y)
    assert losses.shape == (4,)
    assert grads.shape == (4, d)
    assert np.all(np.isfinite(np.asarray(losses)))
    assert np.all(np.isfinite(np.asarray(grads)))


@pytest.mark.parametrize("name", list(M.MODELS))
def test_eval_outputs(name):
    model = M.MODELS[name]
    p = jnp.asarray(model.init(seed=0))
    x, y = _fake_batch(model, 6)
    loss_sum, correct = model.evaluate(p, x, y)
    assert np.isfinite(float(loss_sum))
    assert 0.0 <= float(correct) <= 6.0


def test_logreg_closed_form_matches_autodiff():
    """The Pallas-kernel closed-form grads == vmap(grad) of a jnp-only loss."""
    model = M.LogReg
    p = jnp.asarray(model.init(seed=1))
    x, y = _fake_batch(model, 8, seed=3)

    def loss(flat, xi, yi):
        pp = M.unflatten(flat, model.param_specs())
        logits = xi @ pp["w"] + pp["b"]
        return jax.nn.logsumexp(logits) - logits[yi]

    want_l = jax.vmap(lambda xi, yi: loss(p, xi, yi))(x, y)
    want_g = jax.vmap(jax.grad(loss), in_axes=(None, 0, 0))(p, x, y)
    got_l, got_g = model.per_example(p, x, y)
    np.testing.assert_allclose(got_l, want_l, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(got_g, want_g, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("name", list(M.MODELS))
def test_mean_grad_descends(name):
    """A few mean-gradient steps reduce the batch loss (sanity of signs)."""
    model = M.MODELS[name]
    p = jnp.asarray(model.init(seed=0))
    x, y = _fake_batch(model, 8, seed=5)
    losses0, grads = model.per_example(p, x, y)
    lr = 0.1 if name == "logreg" else 0.05
    for _ in range(5):
        losses, grads = model.per_example(p, x, y)
        p = p - lr * jnp.mean(grads, axis=0)
    losses1, _ = model.per_example(p, x, y)
    assert float(jnp.mean(losses1)) < float(jnp.mean(losses0))


def test_unflatten_roundtrip():
    model = M.TinyTransformer
    specs = model.param_specs()
    d = M.model_dim(model)
    flat = jnp.arange(d, dtype=jnp.float32)
    tree = M.unflatten(flat, specs)
    back = M.flatten_np({k: np.asarray(v) for k, v in tree.items()}, specs)
    np.testing.assert_array_equal(np.asarray(flat), back)


def test_param_layout_offsets_contiguous():
    for model in M.MODELS.values():
        off = 0
        for _, shape in model.param_specs():
            off += int(np.prod(shape))
        assert off == M.model_dim(model)


def test_grad_of_mean_equals_mean_of_per_example():
    """Ordering-unit grads must average to the batch gradient (GCC)."""
    model = M.LogReg
    p = jnp.asarray(model.init(seed=2))
    x, y = _fake_batch(model, 16, seed=9)
    _, grads = model.per_example(p, x, y)

    def batch_loss(flat):
        pp = M.unflatten(flat, model.param_specs())
        logits = x @ pp["w"] + pp["b"]
        logz = jax.nn.logsumexp(logits, axis=-1)
        return jnp.mean(logz - logits[jnp.arange(16), y])

    want = jax.grad(batch_loss)(p)
    np.testing.assert_allclose(jnp.mean(grads, axis=0), want,
                               rtol=1e-4, atol=1e-6)
