"""L1 kernel correctness: Pallas (interpret=True) vs pure-jnp oracles.

Hypothesis sweeps shapes and value ranges; every kernel must agree with its
ref.py oracle to tight tolerance across the sweep.
"""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import balance, matmul, ref, softmax_xent

SETTINGS = dict(max_examples=25, deadline=None,
                suppress_health_check=[hypothesis.HealthCheck.too_slow])


def _arr(rng, shape, scale=1.0):
    return jnp.asarray(rng.normal(scale=scale, size=shape), jnp.float32)


# ---------------------------------------------------------------------------
# balance_step (GraB Algorithm 5 inner step)
# ---------------------------------------------------------------------------

@hypothesis.given(
    d=st.integers(min_value=1, max_value=5000),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
)
@hypothesis.settings(**SETTINGS)
def test_balance_matches_ref(d, seed, scale):
    rng = np.random.default_rng(seed)
    s, m, g = (_arr(rng, (d,), scale) for _ in range(3))
    e1, s1, c1 = balance.balance_step(s, m, g)
    e2, s2, c2 = ref.ref_balance_step(s, m, g)
    assert float(e1) == float(e2)
    np.testing.assert_allclose(s1, s2, rtol=1e-6, atol=1e-6 * scale)
    np.testing.assert_allclose(c1, c2, rtol=1e-6, atol=1e-6 * scale)


@hypothesis.given(
    d=st.integers(min_value=2, max_value=512),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@hypothesis.settings(**SETTINGS)
def test_balance_norm_invariance(d, seed):
    """Algorithm 5 is invariant to rescaling the inputs (paper §5)."""
    rng = np.random.default_rng(seed)
    s, m, g = (_arr(rng, (d,)) for _ in range(3))
    e1, _, _ = balance.balance_step(s, m, g)
    e2, _, _ = balance.balance_step(s * 977.0, m * 977.0, g * 977.0)
    assert float(e1) == float(e2)


def test_balance_sign_reduces_sum():
    """The chosen sign never increases ||s|| vs the opposite sign."""
    rng = np.random.default_rng(7)
    s = _arr(rng, (256,))
    m = jnp.zeros(256)
    for _ in range(50):
        g = _arr(rng, (256,))
        eps, s_new, c = balance.balance_step(s, m, g)
        other = s - eps * c
        assert float(jnp.linalg.norm(s_new)) <= \
            float(jnp.linalg.norm(other)) + 1e-4
        s = s_new


@pytest.mark.parametrize("d,block", [(1, 8), (7, 8), (8, 8), (9, 8),
                                     (2048, 2048), (2049, 2048)])
def test_balance_block_boundaries(d, block):
    rng = np.random.default_rng(d)
    s, m, g = (_arr(rng, (d,)) for _ in range(3))
    e1, s1, c1 = balance.balance_step(s, m, g, block_d=block)
    e2, s2, c2 = ref.ref_balance_step(s, m, g)
    assert float(e1) == float(e2)
    np.testing.assert_allclose(s1, s2, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

@hypothesis.given(
    m=st.integers(min_value=1, max_value=70),
    k=st.integers(min_value=1, max_value=300),
    n=st.integers(min_value=1, max_value=40),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@hypothesis.settings(**SETTINGS)
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (m, k))
    w = _arr(rng, (k, n))
    got = matmul.matmul(x, w)
    want = ref.ref_matmul(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4 * np.sqrt(k))


@pytest.mark.parametrize("shape", [(32, 128, 32), (1, 1, 1),
                                   (33, 129, 31), (64, 784, 10)])
def test_matmul_tile_boundaries(shape):
    m, k, n = shape
    rng = np.random.default_rng(m * k * n)
    x = _arr(rng, (m, k))
    w = _arr(rng, (k, n))
    np.testing.assert_allclose(
        matmul.matmul(x, w), ref.ref_matmul(x, w), rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# softmax_xent
# ---------------------------------------------------------------------------

@hypothesis.given(
    b=st.integers(min_value=1, max_value=130),
    c=st.integers(min_value=2, max_value=64),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    shift=st.sampled_from([0.0, 50.0, -50.0]),
)
@hypothesis.settings(**SETTINGS)
def test_softmax_xent_matches_ref(b, c, seed, shift):
    rng = np.random.default_rng(seed)
    logits = _arr(rng, (b, c), 3.0) + shift  # shift checks max-subtraction
    labels = jnp.asarray(rng.integers(0, c, size=b), jnp.int32)
    l1, d1 = softmax_xent.softmax_xent(logits, labels)
    l2, d2 = ref.ref_softmax_xent(logits, labels)
    np.testing.assert_allclose(l1, l2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(d1, d2, rtol=1e-5, atol=1e-6)


def test_softmax_xent_dlogits_rows_sum_to_zero():
    rng = np.random.default_rng(3)
    logits = _arr(rng, (17, 10))
    labels = jnp.asarray(rng.integers(0, 10, size=17), jnp.int32)
    _, d = softmax_xent.softmax_xent(logits, labels)
    np.testing.assert_allclose(np.sum(np.asarray(d), axis=1),
                               np.zeros(17), atol=1e-5)


def test_softmax_xent_grad_is_autodiff_grad():
    """dlogits from the fused kernel == jax.grad of the CE loss."""
    import jax
    rng = np.random.default_rng(11)
    logits = _arr(rng, (9, 7))
    labels = jnp.asarray(rng.integers(0, 7, size=9), jnp.int32)

    def loss(lg):
        l, _ = ref.ref_softmax_xent(lg, labels)
        return jnp.sum(l)

    want = jax.grad(loss)(logits)
    _, got = softmax_xent.softmax_xent(logits, labels)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# sgd (fused momentum update)
# ---------------------------------------------------------------------------

@hypothesis.given(
    d=st.integers(min_value=1, max_value=5000),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    lr=st.sampled_from([1e-3, 0.1, 1.0]),
    mu=st.sampled_from([0.0, 0.9, 0.99]),
    wd=st.sampled_from([0.0, 1e-4, 0.01]),
)
@hypothesis.settings(**SETTINGS)
def test_sgd_matches_ref(d, seed, lr, mu, wd):
    from compile.kernels import sgd

    rng = np.random.default_rng(seed)
    p, v, g = (_arr(rng, (d,)) for _ in range(3))
    hyper = jnp.asarray([lr, mu, wd], jnp.float32)
    p1, v1 = sgd.sgd_step(p, v, g, hyper)
    p2, v2 = ref.ref_sgd_step(p, v, g, hyper)
    np.testing.assert_allclose(p1, p2, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(v1, v2, rtol=1e-6, atol=1e-6)


def test_sgd_converges_on_quadratic():
    from compile.kernels import sgd

    d = 64
    p = jnp.ones(d) * 5.0
    v = jnp.zeros(d)
    # lr/(1-mu) must stay < 2 for the quadratic: use lr=0.05, mu=0.9.
    hyper = jnp.asarray([0.05, 0.9, 0.0], jnp.float32)
    for _ in range(200):
        p, v = sgd.sgd_step(p, v, p, hyper)  # grad of 0.5||p||^2 is p
    assert float(jnp.linalg.norm(p)) < 1e-2
