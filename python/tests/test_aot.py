"""AOT path: lowering produces loadable HLO text + a consistent manifest."""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot
from compile import model as M


def test_to_hlo_text_roundtrip(tmp_path):
    """HLO text of a tiny jitted fn parses back through xla_client."""
    import jax.numpy as jnp
    from jax._src.lib import xla_client as xc

    def fn(a, b):
        return (a @ b + 1.0,)

    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "HloModule" in text
    # Round-trip through the HLO text parser (what the rust side does).
    mod = xc._xla.hlo_module_from_text(text)
    assert mod is not None


def test_lower_balance_writes_artifact(tmp_path):
    entry = aot.lower_balance(64, str(tmp_path))
    assert entry["dim"] == 64
    text = open(tmp_path / entry["hlo"]).read()
    assert "HloModule" in text


def test_lower_model_manifest_entry(tmp_path):
    entry = aot.lower_model(M.LogReg, str(tmp_path))
    assert entry["dim"] == 7850
    assert entry["batch"] == aot.BATCH["logreg"]
    assert os.path.exists(tmp_path / entry["grad_hlo"])
    assert os.path.exists(tmp_path / entry["eval_hlo"])
    init = np.fromfile(tmp_path / entry["init_params"], dtype="<f4")
    assert init.shape == (7850,)
    total = sum(p["size"] for p in entry["param_layout"])
    assert total == entry["dim"]
    offs = [p["offset"] for p in entry["param_layout"]]
    assert offs == sorted(offs) and offs[0] == 0


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__),
                                    "../../artifacts/manifest.json")),
    reason="artifacts not built")
def test_built_manifest_is_consistent():
    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    man = json.load(open(os.path.join(root, "manifest.json")))
    assert man["format"] == 1
    names = {m["name"] for m in man["models"]}
    assert names == set(M.MODELS)
    for entry in man["models"]:
        model = M.MODELS[entry["name"]]
        assert entry["dim"] == M.model_dim(model)
        for key in ("grad_hlo", "eval_hlo", "init_params"):
            assert os.path.exists(os.path.join(root, entry[key])), entry[key]
    for entry in man["balance"]:
        assert os.path.exists(os.path.join(root, entry["hlo"]))
