//! Fig. 3 ablation as a runnable example: are good permutations fixed?
//!
//! Runs the convex task (mnist/logreg) with: full GraB, 1-step GraB
//! (freeze after epoch 0), Retrain-from-GraB (replay a finished run's
//! final order) and RR, printing the loss curves side by side.
//!
//! ```bash
//! cargo run --release --example ablation_fixed_order
//! ```

use anyhow::Result;

use grab::config::{OrderingKind, Task, TrainConfig};
use grab::runtime::Runtime;
use grab::train::Trainer;

fn main() -> Result<()> {
    let rt = Runtime::open("artifacts")?;
    let epochs = 8;

    let base = |ordering: OrderingKind| {
        let mut cfg = TrainConfig::for_task(Task::Mnist);
        cfg.ordering = ordering;
        cfg.epochs = epochs;
        cfg.n_examples = 1024;
        cfg.n_eval = 512;
        cfg.lr = 0.05;
        cfg.seed = 0;
        cfg
    };

    // Source run for the retrain order.
    eprintln!("[ablation] full GraB run (also the retrain source)");
    let mut grab_t = Trainer::new(base(OrderingKind::GraB), &rt, None)?;
    let grab_res = grab_t.run()?;

    let mut curves: Vec<(&str, Vec<f64>)> = vec![(
        "grab",
        grab_res.epochs.iter().map(|m| m.train_loss).collect(),
    )];
    for (name, ordering) in [
        ("rr", OrderingKind::RandomReshuffle),
        ("grab-1step", OrderingKind::OneStepGraB),
    ] {
        eprintln!("[ablation] {name}");
        let mut t = Trainer::new(base(ordering), &rt, None)?;
        let r = t.run()?;
        curves.push((
            name,
            r.epochs.iter().map(|m| m.train_loss).collect(),
        ));
    }
    eprintln!("[ablation] grab-retrain");
    let mut t = Trainer::new(
        base(OrderingKind::RetrainFromGraB),
        &rt,
        Some(grab_res.final_order.clone()),
    )?;
    let r = t.run()?;
    curves.push((
        "grab-retrain",
        r.epochs.iter().map(|m| m.train_loss).collect(),
    ));

    println!("\ntrain loss per epoch (mnist/logreg — convex):");
    print!("epoch");
    for (name, _) in &curves {
        print!(" {name:>13}");
    }
    println!();
    for e in 0..epochs {
        print!("{e:>5}");
        for (_, c) in &curves {
            print!(" {:>13.4}", c[e]);
        }
        println!();
    }
    println!(
        "\nPaper's Fig. 3 takeaway on the convex task: grab-retrain \
         tracks full grab (a good FIXED order exists), while grab-1step \
         lags (one epoch of balancing is not enough — Challenge II)."
    );
    Ok(())
}
