//! Herding toy (paper Fig. 1b): visualize how balancing + reordering
//! flattens prefix-sum norms on random vectors — ASCII plot edition.
//!
//! ```bash
//! cargo run --release --example herding_toy [-- --n 10000 --d 128]
//! ```

use anyhow::Result;

use grab::balance::DeterministicBalancer;
use grab::herding::offline::herd;
use grab::herding::prefix_trajectory;
use grab::util::cli::Args;
use grab::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let n = args.usize_or("n", 10_000)?;
    let d = args.usize_or("d", 128)?;
    let passes = args.usize_or("passes", 10)?;
    args.reject_unknown()?;

    let mut rng = Rng::new(0);
    let vs: Vec<Vec<f32>> = (0..n)
        .map(|_| (0..d).map(|_| rng.f32()).collect())
        .collect();
    let original: Vec<usize> = (0..n).collect();
    let mut balancer = DeterministicBalancer;
    let (herded, stats) = herd(&mut balancer, &vs, passes);

    let t_orig = prefix_trajectory(&vs, &original);
    let t_herd = prefix_trajectory(&vs, &herded);

    println!("herding toy: n={n} vectors in [0,1]^{d}");
    println!("\npass-by-pass herding bound (ℓ∞ / ℓ2):");
    for s in &stats {
        println!(
            "  pass {:>2}: {:>10.3} / {:>10.3}",
            s.pass, s.herding_inf, s.herding_l2
        );
    }

    // ASCII sparkline of both prefix curves (60 buckets).
    let buckets = 60usize;
    let max = t_orig.iter().cloned().fold(f32::MIN, f32::max);
    let sample = |t: &[f32]| -> Vec<f32> {
        (0..buckets)
            .map(|b| t[(b * (t.len() - 1)) / (buckets - 1)])
            .collect()
    };
    let bar = |v: f32| -> char {
        const RAMP: [char; 8] =
            [' ', '.', ':', '-', '=', '+', '*', '#'];
        RAMP[((v / max * 7.0).round() as usize).min(7)]
    };
    let line = |t: &[f32]| -> String {
        sample(t).into_iter().map(bar).collect()
    };
    println!("\nprefix-sum ℓ2 norm vs k (left→right = k: 1→n):");
    println!("  original |{}| max={:.1}", line(&t_orig), max);
    println!(
        "  herded   |{}| max={:.1}",
        line(&t_herd),
        t_herd.iter().cloned().fold(f32::MIN, f32::max)
    );
    println!(
        "\nThe original order's prefix sums bulge (random walk ~ √k); the \
         herded order keeps every prefix near zero — Fig. 1b's picture."
    );
    Ok(())
}
