//! Layer ablation: the GraB balance step executed two ways —
//! (a) rust-native fused loops (the default L3 hot path) and
//! (b) the L1 Pallas kernel AOT-compiled to HLO, loaded via PJRT —
//! cross-validated sign-for-sign and timed.
//!
//! ```bash
//! cargo run --release --example balance_kernel [-- --d 7850 --steps 200]
//! ```

use anyhow::Result;

use grab::runtime::Runtime;
use grab::tensor;
use grab::util::cli::Args;
use grab::util::rng::Rng;
use grab::util::timer::Stopwatch;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let d = args.usize_or("d", 7850)?;
    let steps = args.usize_or("steps", 200)?;
    args.reject_unknown()?;

    let rt = Runtime::open("artifacts")?;
    let kernel = rt.balance_executor(d)?;
    let mut rng = Rng::new(0);

    // Shared stream of (g, m) pairs.
    let gs: Vec<Vec<f32>> = (0..steps)
        .map(|_| (0..d).map(|_| rng.gauss() as f32).collect())
        .collect();
    let m: Vec<f32> = (0..d).map(|_| rng.gauss() as f32 * 0.1).collect();

    // (a) native path.
    let mut s_native = vec![0.0f32; d];
    let mut native_signs = Vec::with_capacity(steps);
    let sw = Stopwatch::start();
    for g in &gs {
        let eps = if tensor::dot_centered(&s_native, g, &m) < 0.0 {
            1.0
        } else {
            -1.0
        };
        tensor::axpy_centered(eps, g, &m, &mut s_native);
        native_signs.push(eps);
    }
    let native_secs = sw.secs();

    // (b) Pallas/HLO kernel path.
    let mut s_kernel = vec![0.0f32; d];
    let mut kernel_signs = Vec::with_capacity(steps);
    let sw = Stopwatch::start();
    for g in &gs {
        let eps = kernel.step(&mut s_kernel, &m, g)?;
        kernel_signs.push(eps);
    }
    let kernel_secs = sw.secs();

    assert_eq!(native_signs, kernel_signs,
               "native and Pallas kernel signs must agree");
    let max_dev = s_native
        .iter()
        .zip(&s_kernel)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);

    println!("balance step x{steps} at d={d}:");
    println!(
        "  native fused loops : {:>10.1} ns/step",
        native_secs / steps as f64 * 1e9
    );
    println!(
        "  pallas/HLO via PJRT: {:>10.1} ns/step  \
         ({}x native; dominated by per-call buffer upload)",
        kernel_secs / steps as f64 * 1e9,
        (kernel_secs / native_secs).round()
    );
    println!("  signs identical; max |s| deviation = {max_dev:.2e}");
    println!(
        "\nThe coordinator defaults to the native path and uses the \
         kernel artifact for cross-validation (this binary + tests); on \
         real TPU the kernel path amortizes by fusing into the L2 step."
    );
    Ok(())
}
