//! Streaming LM pipeline — the WikiText-2-like task (LSTM) through the
//! threaded data pipeline, with ReduceLROnPlateau (the paper's recipe) and
//! backpressure statistics.
//!
//! ```bash
//! cargo run --release --example lm_pipeline [-- --epochs 8 --n 512]
//! ```

use anyhow::Result;

use grab::config::{OrderingKind, Task, TrainConfig};
use grab::pipeline::PipelineTrainer;
use grab::runtime::Runtime;
use grab::train::Trainer;
use grab::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let epochs = args.usize_or("epochs", 8)?;
    let n = args.usize_or("n", 512)?;
    args.reject_unknown()?;

    let rt = Runtime::open("artifacts")?;

    for ordering in [OrderingKind::RandomReshuffle, OrderingKind::GraB] {
        let mut cfg = TrainConfig::for_task(Task::Wiki);
        cfg.ordering = ordering;
        cfg.epochs = epochs;
        cfg.n_examples = n;
        cfg.n_eval = 256;
        cfg.accum_steps = 2;
        cfg.seed = 0;

        // Pipelined epoch pass (throughput), then a sync run for eval
        // curves (perplexity).
        println!("=== {} — threaded pipeline ===", ordering.name());
        let mut pipe = PipelineTrainer::new(cfg.clone(), &rt)?;
        let presult = pipe.run()?;
        for m in &presult.epochs {
            println!("{}", m.line("pipeline"));
        }
        println!(
            "backpressure: {} batches, {} loader stalls, {} grad stalls",
            pipe.stats.batches,
            pipe.stats.loader_stalls,
            pipe.stats.grad_stalls
        );

        println!("--- {} — sync with eval ---", ordering.name());
        let mut t = Trainer::new(cfg, &rt, None)?;
        let r = t.run()?;
        for m in &r.epochs {
            let ppl = m.eval_loss.map(f64::exp);
            match ppl {
                Some(p) => println!(
                    "{}  eval_ppl={p:.2}",
                    m.line(ordering.name())
                ),
                None => println!("{}", m.line(ordering.name())),
            }
        }
        println!();
    }
    println!(
        "Sequences are the ordering units (one bptt window each), matching \
         the paper's LSTM granularity; perplexity = exp(mean CE)."
    );
    Ok(())
}
