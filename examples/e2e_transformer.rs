//! End-to-end validation driver (recorded in EXPERIMENTS.md §E2E).
//!
//! Trains the tiny transformer (~73k params — the BERT-Tiny stand-in at
//! this testbed's scale) on the synthetic GLUE-like task for several
//! hundred optimizer steps under RR and GraB, exercising every layer of
//! the stack:
//!
//!   L3 threaded pipeline (loader → PJRT grad stage → balance/optimize)
//!     → L2 vmap-grad transformer HLO
//!       → (same artifact family whose logreg path embeds the L1 Pallas
//!          kernels; the balance step itself is the L3 hot path)
//!
//! Logs the loss curve, eval accuracy, the measured per-epoch herding
//! balance bound, and pipeline backpressure stats.
//!
//! ```bash
//! cargo run --release --example e2e_transformer
//! ```

use anyhow::Result;

use grab::config::{OrderingKind, Task, TrainConfig};
use grab::pipeline::PipelineTrainer;
use grab::runtime::Runtime;
use grab::train::Trainer;

fn main() -> Result<()> {
    let rt = Runtime::open("artifacts")?;
    let entry = rt.manifest.model("transformer")?;
    println!(
        "e2e driver: transformer d={} params, {} layers of attention \
         (see python/compile/model.py), PJRT platform {}",
        entry.dim,
        2,
        rt.platform()
    );

    let epochs = 12;
    let n = 1024;
    // 1024 units / (B=8 * accum=4) = 32 optimizer steps/epoch
    // -> 384 steps across the run.
    let accum = 4;

    let mut finals = Vec::new();
    for ordering in [OrderingKind::RandomReshuffle, OrderingKind::GraB] {
        let mut cfg = TrainConfig::for_task(Task::Glue);
        cfg.ordering = ordering;
        cfg.epochs = epochs;
        cfg.n_examples = n;
        cfg.n_eval = 512;
        cfg.accum_steps = accum;
        cfg.seed = 0;

        println!("\n=== {} (sync trainer, with eval) ===", ordering.name());
        let mut trainer = Trainer::new(cfg.clone(), &rt, None)?;
        let result = trainer.run()?;
        for m in &result.epochs {
            println!("{}", m.line(ordering.name()));
        }
        let last = result.epochs.last().unwrap();
        finals.push((
            ordering.name(),
            last.train_loss,
            last.eval_acc.unwrap_or(f64::NAN),
            result.epochs.iter().map(|e| e.optimizer_steps).sum::<usize>(),
        ));

        // Same config through the threaded pipeline: must produce the
        // identical loss curve (semantics-preserving overlap), plus
        // backpressure stats.
        println!("--- {} (threaded pipeline) ---", ordering.name());
        let mut pipe = PipelineTrainer::new(cfg, &rt)?;
        let presult = pipe.run()?;
        let sync_losses: Vec<f64> =
            result.epochs.iter().map(|m| m.train_loss).collect();
        let pipe_losses: Vec<f64> =
            presult.epochs.iter().map(|m| m.train_loss).collect();
        let max_dev = sync_losses
            .iter()
            .zip(&pipe_losses)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        println!(
            "pipeline vs sync max |Δtrain_loss| = {max_dev:.2e} \
             ({} batches, {} loader stalls, {} grad stalls)",
            pipe.stats.batches,
            pipe.stats.loader_stalls,
            pipe.stats.grad_stalls
        );
        assert!(
            max_dev < 1e-6,
            "pipeline must match sync semantics exactly"
        );
    }

    println!("\n=== summary ===");
    println!(
        "{:<6} {:>12} {:>10} {:>16}",
        "order", "train_loss", "eval_acc", "optimizer_steps"
    );
    for (name, loss, acc, steps) in &finals {
        println!("{name:<6} {loss:>12.4} {acc:>10.3} {steps:>16}");
    }
    println!(
        "\nAll three layers composed: rust pipeline -> PJRT-loaded HLO \
         (vmap-grad transformer) -> per-example grads balanced online by \
         GraB. Record: EXPERIMENTS.md §E2E."
    );
    Ok(())
}
