//! Quickstart — train the paper's MNIST task (logistic regression) with
//! GraB vs Random Reshuffling for a few epochs and print both loss curves.
//!
//! ```bash
//! make artifacts            # once: AOT-lower the JAX/Pallas models
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;

use grab::config::{OrderingKind, Task, TrainConfig};
use grab::runtime::Runtime;
use grab::train::Trainer;

fn main() -> Result<()> {
    let rt = Runtime::open("artifacts")?;
    println!("PJRT platform: {}\n", rt.platform());

    let mut curves = Vec::new();
    for ordering in [OrderingKind::RandomReshuffle, OrderingKind::GraB] {
        let mut cfg = TrainConfig::for_task(Task::Mnist);
        cfg.ordering = ordering;
        cfg.epochs = 8;
        cfg.n_examples = 1024;
        cfg.n_eval = 512;
        cfg.lr = 0.05; // GraB reuses RR's hyperparameters (paper §6)
        cfg.seed = 0;

        println!("=== {} ===", ordering.name());
        let mut trainer = Trainer::new(cfg, &rt, None)?;
        let result = trainer.run()?;
        for m in &result.epochs {
            println!("{}", m.line(ordering.name()));
        }
        println!();
        curves.push((
            ordering.name(),
            result
                .epochs
                .iter()
                .map(|m| m.train_loss)
                .collect::<Vec<_>>(),
        ));
    }

    // Side-by-side comparison.
    println!("epoch   {:>12} {:>12}", curves[0].0, curves[1].0);
    for e in 0..curves[0].1.len() {
        println!(
            "{e:>5}   {:>12.4} {:>12.4}",
            curves[0].1[e], curves[1].1[e]
        );
    }
    let last = curves[0].1.len() - 1;
    if curves[1].1[last] <= curves[0].1[last] {
        println!("\nGraB reached a lower final training loss than RR, as \
                  in the paper's Fig. 2a.");
    } else {
        println!("\nNote: on this tiny run RR ended lower; GraB's \
                  advantage grows with epochs (see `grab exp fig2`).");
    }
    Ok(())
}
