#!/usr/bin/env python3
"""Reference mirror of `grab audit` (rust/src/audit/) for hosts without a
Rust toolchain.

The Rust implementation is canonical — this mirror exists so the audit can
be run (and its rule set prototyped) on snapshot hosts that cannot build
the crate, the same provenance arrangement as tools/bench_mirror.c for the
perf trajectory. Keep the two implementations in sync: the fixture suite in
rust/tests/audit.rs is the semantics contract, and docs/audit.md documents
every rule this file implements.

Usage:
    python3 tools/audit_mirror.py [--root rust]

Exit status: 0 on a clean tree, 1 when any violation is found.
"""

import os
import re
import sys

WORD = re.compile(r"[A-Za-z0-9_]")

INT_TYPES = {
    "u8", "u16", "u32", "u64", "u128", "usize",
    "i8", "i16", "i32", "i64", "i128", "isize",
}

D02_DIRS = (
    "src/ordering/", "src/balance/", "src/herding/", "src/tensor/",
    "src/train/",
)
D03_ALLOW = {
    "src/util/timer.rs", "src/ordering/sharded.rs", "src/service/client.rs",
}
W01_FILES = {
    "src/util/ser.rs", "src/ordering/transport/codec.rs",
    "src/service/http.rs",
}
SAFETY_LOOKBACK = 6

RULE_IDS = {"D01", "D02", "D03", "D04", "S01", "W01"}


def scan(text):
    """Split source into (code, comment_lines): code has comment and
    string/char-literal contents blanked to spaces (newlines kept);
    comment_lines[i] is the comment text appearing on line i (0-based)."""
    b = text
    n = len(b)
    code = [" "] * n
    comm = [" "] * n
    i = 0

    def ident_char(c):
        return bool(WORD.match(c))

    while i < n:
        c = b[i]
        prev_ident = i > 0 and ident_char(b[i - 1])
        if c == "/" and i + 1 < n and b[i + 1] == "/":
            while i < n and b[i] != "\n":
                comm[i] = b[i]
                i += 1
            continue
        if c == "/" and i + 1 < n and b[i + 1] == "*":
            depth = 0
            while i < n:
                if b[i] == "/" and i + 1 < n and b[i + 1] == "*":
                    depth += 1
                    comm[i] = b[i]
                    comm[i + 1] = b[i + 1]
                    i += 2
                elif b[i] == "*" and i + 1 < n and b[i + 1] == "/":
                    depth -= 1
                    comm[i] = b[i]
                    comm[i + 1] = b[i + 1]
                    i += 2
                    if depth == 0:
                        break
                else:
                    comm[i] = b[i]
                    i += 1
            continue
        if not prev_ident and (
            c == "r" or (c == "b" and i + 1 < n and b[i + 1] == "r")
        ):
            j = i + (2 if c == "b" else 1)
            k = 0
            while j + k < n and b[j + k] == "#":
                k += 1
            if j + k < n and b[j + k] == '"':
                # Raw (byte) string: blank through `"` + k hashes.
                i = j + k + 1
                term = '"' + "#" * k
                end = b.find(term, i)
                i = n if end < 0 else end + len(term)
                continue
        if not prev_ident and c == "b" and i + 1 < n and b[i + 1] in "\"'":
            i += 1  # byte string/char: fall through on the quote
            c = b[i]
        if c == '"':
            i += 1
            while i < n:
                if b[i] == "\\":
                    i += 2
                elif b[i] == '"':
                    i += 1
                    break
                else:
                    i += 1
            continue
        if c == "'":
            nxt = b[i + 1] if i + 1 < n else ""
            nxt2 = b[i + 2] if i + 2 < n else ""
            if nxt and nxt != "\\" and ident_char(nxt) and nxt2 != "'":
                # Lifetime or loop label: keep the quote as code.
                code[i] = c
                i += 1
                continue
            i += 1
            while i < n and b[i] != "\n":
                if b[i] == "\\":
                    i += 2
                elif b[i] == "'":
                    i += 1
                    break
                else:
                    i += 1
            continue
        code[i] = c
        i += 1

    for idx, ch in enumerate(b):
        if ch == "\n":
            code[idx] = "\n"
            comm[idx] = "\n"
    return "".join(code), "".join(comm).split("\n")


def word_at(code, off, length):
    before = code[off - 1] if off > 0 else " "
    after = code[off + length] if off + length < len(code) else " "
    return not WORD.match(before) and not WORD.match(after)


def find_words(code, needle):
    out = []
    start = 0
    while True:
        off = code.find(needle, start)
        if off < 0:
            return out
        if word_at(code, off, len(needle)):
            out.append(off)
        start = off + 1


def skip_ws(code, i):
    while i < len(code) and code[i] in " \t\n\r":
        i += 1
    return i


def balanced_span(code, i):
    """i points at '('; return index just past the matching ')'."""
    depth = 0
    while i < len(code):
        if code[i] == "(":
            depth += 1
        elif code[i] == ")":
            depth -= 1
            if depth == 0:
                return i + 1
        i += 1
    return i


def line_of(text, off):
    return text.count("\n", 0, off) + 1


def check_d01(code):
    hits = []
    for off in find_words(code, "partial_cmp"):
        j = skip_ws(code, off + len("partial_cmp"))
        if j >= len(code) or code[j] != "(":
            continue
        j = skip_ws(code, balanced_span(code, j))
        if j < len(code) and code[j] == ".":
            j = skip_ws(code, j + 1)
            for m in ("unwrap", "expect"):
                if code.startswith(m, j) and word_at(code, j, len(m)):
                    hits.append((off, "`partial_cmp(..).%s()` panics on "
                                 "NaN; compare floats with `total_cmp`"
                                 % m))
    for fn in ("sort_by", "sort_unstable_by", "max_by", "min_by"):
        for off in find_words(code, fn):
            j = skip_ws(code, off + len(fn))
            if j >= len(code) or code[j] != "(":
                continue
            body = code[j:balanced_span(code, j)]
            if find_words(body, "partial_cmp"):
                hits.append((off, "`%s` comparator uses `partial_cmp`: "
                             "NaN ordering is undefined; use `total_cmp`"
                             % fn))
    return hits


def check_file(rel, text):
    code, comments = scan(text)
    findings = []  # (rule, line, message)

    for off, msg in check_d01(code):
        findings.append(("D01", line_of(code, off), msg))

    if any(rel.startswith(d) for d in D02_DIRS):
        for name in ("HashMap", "HashSet"):
            for off in find_words(code, name):
                findings.append(("D02", line_of(code, off),
                                 "`%s` iteration order is randomized per "
                                 "process and can leak into an epoch "
                                 "order; use BTreeMap/BTreeSet/Vec"
                                 % name))

    if rel.startswith("src/") and rel not in D03_ALLOW:
        for needle in ("Instant::now", "SystemTime"):
            for off in find_words(code, needle):
                findings.append(("D03", line_of(code, off),
                                 "wall-clock read (`%s`) outside the "
                                 "allowlisted clock sites can reach a "
                                 "static-path order" % needle))

    for off in find_words(code, "unsafe"):
        line = line_of(code, off)
        lo = max(0, line - 1 - SAFETY_LOOKBACK)
        covered = any("SAFETY:" in comments[k]
                      for k in range(lo, min(line, len(comments))))
        if not covered:
            findings.append(("S01", line,
                             "`unsafe` without a `// SAFETY:` comment in "
                             "the %d lines above" % SAFETY_LOOKBACK))

    if rel.startswith("src/tensor/"):
        for off in find_words(code, "mul_add"):
            findings.append(("D04", line_of(code, off),
                             "`mul_add` fuses mul+add (FMA): contract 7 "
                             "bit-equality needs separate mul then add"))
        idx = 0
        while True:
            off = code.find("fmadd", idx)
            if off < 0:
                break
            findings.append(("D04", line_of(code, off),
                             "FMA intrinsic: contract 7 bit-equality "
                             "needs separate mul then add"))
            idx = off + 1

    if rel in W01_FILES:
        for off in find_words(code, "as"):
            j = skip_ws(code, off + 2)
            m = re.match(r"[A-Za-z0-9_]+", code[j:j + 8])
            if m and m.group(0) in INT_TYPES:
                findings.append(("W01", line_of(code, off),
                                 "bare `as %s` cast in a wire layer can "
                                 "truncate silently; use the checked "
                                 "conversions in util::ser" % m.group(0)))

    # Waivers: `// audit: allow(RULE, reason = "...")` covers same-rule
    # findings on its own line and the next line.
    waivers = []
    for lineno0, ctext in enumerate(comments):
        marker = "audit: allow("
        pos = ctext.find(marker)
        if pos < 0:
            continue
        lineno = lineno0 + 1
        body = ctext[pos + len(marker):]
        m = re.match(
            r"\s*([A-Z][0-9]{2})\s*,\s*reason\s*=\s*\"([^\"]*)\"\s*\)",
            body,
        )
        if not m or not m.group(2).strip() or m.group(1) not in RULE_IDS:
            findings.append(("A00", lineno,
                             "malformed waiver: expected `audit: "
                             "allow(<rule>, reason = \"...\")` with a "
                             "known rule and a non-empty reason"))
            continue
        waivers.append({"rule": m.group(1), "line": lineno, "used": False})

    kept, waived = [], []
    for f in findings:
        rule, line, _ = f
        hit = None
        for w in waivers:
            if w["rule"] == rule and line in (w["line"], w["line"] + 1):
                hit = w
                break
        if hit:
            hit["used"] = True
            waived.append(f)
        else:
            kept.append(f)
    for w in waivers:
        if not w["used"]:
            kept.append(("A00", w["line"],
                         "stale waiver: no %s finding on this or the "
                         "next line" % w["rule"]))
    kept.sort(key=lambda f: f[1])
    return kept, waived


def main():
    root = "rust"
    args = sys.argv[1:]
    if args[:1] == ["--root"]:
        root = args[1]
    files = []
    for sub in ("src", "tests", "benches"):
        base = os.path.join(root, sub)
        for dirpath, _, names in os.walk(base):
            for name in sorted(names):
                if name.endswith(".rs"):
                    files.append(os.path.join(dirpath, name))
    files.sort()
    total, waived_total = 0, 0
    for path in files:
        rel = os.path.relpath(path, root).replace(os.sep, "/")
        with open(path, encoding="utf-8") as fh:
            text = fh.read()
        kept, waived = check_file(rel, text)
        waived_total += len(waived)
        for rule, line, msg in kept:
            print("%s:%d: %s: %s" % (path, line, rule, msg))
            total += 1
    print("audit(mirror): %d violation(s), %d waiver(s) honored, "
          "%d file(s) scanned" % (total, waived_total, len(files)),
          file=sys.stderr)
    sys.exit(1 if total else 0)


if __name__ == "__main__":
    main()
