/* C mirror of the rust/src/tensor kernels, used to cross-check the numbers
 * the `grab bench` runner records (see docs/perf.md).
 *
 * The scalar functions transcribe the Rust reference kernels line-for-line
 * (8-lane accumulator arrays, chunks_exact(8) main loop, scalar tail,
 * in-order lane fold).  Compiled at -O3 for the default x86-64 target they
 * see the same SSE2 auto-vectorization rustc applies to the Rust originals.
 * The avx2_* functions transcribe tensor/simd.rs: one 256-bit vector per
 * 8-lane accumulator group, separate mul then add (no FMA), identical tail
 * and fold — so every function pair must agree bit-for-bit, which main()
 * asserts before timing anything.
 *
 * Build:  gcc -O3 -o bench_mirror bench_mirror.c -lm
 * Run:    ./bench_mirror [--quick]              (human-readable table)
 *         ./bench_mirror [--quick] --json FILE  (BENCH_*.json snapshot)
 *
 * The --json mode emits the same schema as `grab bench` (schema_version
 * 1) with "runner": "c-mirror" and case/kernel keys matching the Rust
 * runner's rows, so a snapshot recorded on a machine without a Rust
 * toolchain stays comparable with later grab-bench snapshots (see
 * docs/perf.md §Provenance).  It mirrors the tensor-level cases and the
 * single-policy GraB/PairBalance observe loops; the transport and PJRT
 * cases need the Rust runner.
 */

#include <immintrin.h>
#include <math.h>
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>

/* ---------------- scalar reference kernels (mirror tensor/mod.rs) ------- */

static float dot_scalar(const float *a, const float *b, size_t len) {
    size_t main = len - len % 8;
    float acc[8] = {0};
    for (size_t off = 0; off < main; off += 8)
        for (int lane = 0; lane < 8; lane++)
            acc[lane] += a[off + lane] * b[off + lane];
    float tail = 0.0f;
    for (size_t i = main; i < len; i++)
        tail += a[i] * b[i];
    float s = 0.0f;
    for (int lane = 0; lane < 8; lane++)
        s += acc[lane];
    return s + tail;
}

static float dot_centered_scalar(const float *s, const float *g,
                                 const float *m, size_t len) {
    size_t main = len - len % 8;
    float acc[8] = {0};
    for (size_t off = 0; off < main; off += 8)
        for (int lane = 0; lane < 8; lane++)
            acc[lane] += s[off + lane] * (g[off + lane] - m[off + lane]);
    float tail = 0.0f;
    for (size_t i = main; i < len; i++)
        tail += s[i] * (g[i] - m[i]);
    float r = 0.0f;
    for (int lane = 0; lane < 8; lane++)
        r += acc[lane];
    return r + tail;
}

static float dot_diff_scalar(const float *s, const float *a, const float *b,
                             size_t len) {
    size_t main = len - len % 8;
    float acc[8] = {0};
    for (size_t off = 0; off < main; off += 8)
        for (int lane = 0; lane < 8; lane++)
            acc[lane] += s[off + lane] * (a[off + lane] - b[off + lane]);
    float tail = 0.0f;
    for (size_t i = main; i < len; i++)
        tail += s[i] * (a[i] - b[i]);
    float r = 0.0f;
    for (int lane = 0; lane < 8; lane++)
        r += acc[lane];
    return r + tail;
}

static void axpy_scalar(float alpha, const float *x, float *y, size_t len) {
    size_t main = len - len % 8;
    for (size_t off = 0; off < main; off += 8)
        for (int lane = 0; lane < 8; lane++)
            y[off + lane] += alpha * x[off + lane];
    for (size_t i = main; i < len; i++)
        y[i] += alpha * x[i];
}

static void axpy_diff_scalar(float eps, const float *a, const float *b,
                             float *s, size_t len) {
    size_t main = len - len % 8;
    for (size_t off = 0; off < main; off += 8)
        for (int lane = 0; lane < 8; lane++)
            s[off + lane] += eps * (a[off + lane] - b[off + lane]);
    for (size_t i = main; i < len; i++)
        s[i] += eps * (a[i] - b[i]);
}

static void sign_sum_accum_scalar(float eps, const float *g, float *signed_,
                                  float *sum, size_t len) {
    size_t main = len - len % 8;
    for (size_t off = 0; off < main; off += 8)
        for (int lane = 0; lane < 8; lane++) {
            float gl = g[off + lane];
            signed_[off + lane] += eps * gl;
            sum[off + lane] += gl;
        }
    for (size_t i = main; i < len; i++) {
        float gl = g[i];
        signed_[i] += eps * gl;
        sum[i] += gl;
    }
}

static void fold_signed_block_scalar(const float *signed_, float net,
                                     const float *m, float *s, size_t len) {
    size_t main = len - len % 8;
    for (size_t off = 0; off < main; off += 8)
        for (int lane = 0; lane < 8; lane++)
            s[off + lane] += signed_[off + lane] - net * m[off + lane];
    for (size_t i = main; i < len; i++)
        s[i] += signed_[i] - net * m[i];
}

static void grab_update_scalar(float eps, float inv_n, const float *g,
                               const float *m, float *s, float *fresh,
                               size_t len) {
    size_t main = len - len % 8;
    for (size_t off = 0; off < main; off += 8)
        for (int lane = 0; lane < 8; lane++) {
            float gl = g[off + lane];
            s[off + lane] += eps * (gl - m[off + lane]);
            fresh[off + lane] += inv_n * gl;
        }
    for (size_t i = main; i < len; i++) {
        float gl = g[i];
        s[i] += eps * (gl - m[i]);
        fresh[i] += inv_n * gl;
    }
}

/* ---------------- AVX2 kernels (mirror tensor/simd.rs) ------------------ */

__attribute__((target("avx2"))) static float
dot_avx2(const float *a, const float *b, size_t len) {
    size_t main = len - len % 8;
    __m256 acc = _mm256_setzero_ps();
    for (size_t off = 0; off < main; off += 8) {
        __m256 av = _mm256_loadu_ps(a + off);
        __m256 bv = _mm256_loadu_ps(b + off);
        acc = _mm256_add_ps(acc, _mm256_mul_ps(av, bv));
    }
    float lanes[8];
    _mm256_storeu_ps(lanes, acc);
    float tail = 0.0f;
    for (size_t i = main; i < len; i++)
        tail += a[i] * b[i];
    float s = 0.0f;
    for (int lane = 0; lane < 8; lane++)
        s += lanes[lane];
    return s + tail;
}

__attribute__((target("avx2"))) static float
dot_centered_avx2(const float *s, const float *g, const float *m,
                  size_t len) {
    size_t main = len - len % 8;
    __m256 acc = _mm256_setzero_ps();
    for (size_t off = 0; off < main; off += 8) {
        __m256 sv = _mm256_loadu_ps(s + off);
        __m256 gv = _mm256_loadu_ps(g + off);
        __m256 mv = _mm256_loadu_ps(m + off);
        acc = _mm256_add_ps(acc, _mm256_mul_ps(sv, _mm256_sub_ps(gv, mv)));
    }
    float lanes[8];
    _mm256_storeu_ps(lanes, acc);
    float tail = 0.0f;
    for (size_t i = main; i < len; i++)
        tail += s[i] * (g[i] - m[i]);
    float r = 0.0f;
    for (int lane = 0; lane < 8; lane++)
        r += lanes[lane];
    return r + tail;
}

__attribute__((target("avx2"))) static float
dot_diff_avx2(const float *s, const float *a, const float *b, size_t len) {
    size_t main = len - len % 8;
    __m256 acc = _mm256_setzero_ps();
    for (size_t off = 0; off < main; off += 8) {
        __m256 sv = _mm256_loadu_ps(s + off);
        __m256 av = _mm256_loadu_ps(a + off);
        __m256 bv = _mm256_loadu_ps(b + off);
        acc = _mm256_add_ps(acc, _mm256_mul_ps(sv, _mm256_sub_ps(av, bv)));
    }
    float lanes[8];
    _mm256_storeu_ps(lanes, acc);
    float tail = 0.0f;
    for (size_t i = main; i < len; i++)
        tail += s[i] * (a[i] - b[i]);
    float r = 0.0f;
    for (int lane = 0; lane < 8; lane++)
        r += lanes[lane];
    return r + tail;
}

__attribute__((target("avx2"))) static void
axpy_avx2(float alpha, const float *x, float *y, size_t len) {
    size_t main = len - len % 8;
    __m256 al = _mm256_set1_ps(alpha);
    for (size_t off = 0; off < main; off += 8) {
        __m256 xv = _mm256_loadu_ps(x + off);
        __m256 yv = _mm256_loadu_ps(y + off);
        _mm256_storeu_ps(y + off,
                         _mm256_add_ps(yv, _mm256_mul_ps(al, xv)));
    }
    for (size_t i = main; i < len; i++)
        y[i] += alpha * x[i];
}

__attribute__((target("avx2"))) static void
axpy_diff_avx2(float eps, const float *a, const float *b, float *s,
               size_t len) {
    size_t main = len - len % 8;
    __m256 ev = _mm256_set1_ps(eps);
    for (size_t off = 0; off < main; off += 8) {
        __m256 av = _mm256_loadu_ps(a + off);
        __m256 bv = _mm256_loadu_ps(b + off);
        __m256 sv = _mm256_loadu_ps(s + off);
        __m256 d = _mm256_sub_ps(av, bv);
        _mm256_storeu_ps(s + off,
                         _mm256_add_ps(sv, _mm256_mul_ps(ev, d)));
    }
    for (size_t i = main; i < len; i++)
        s[i] += eps * (a[i] - b[i]);
}

__attribute__((target("avx2"))) static void
sign_sum_accum_avx2(float eps, const float *g, float *signed_, float *sum,
                    size_t len) {
    size_t main = len - len % 8;
    __m256 ev = _mm256_set1_ps(eps);
    for (size_t off = 0; off < main; off += 8) {
        __m256 gv = _mm256_loadu_ps(g + off);
        __m256 sv = _mm256_loadu_ps(signed_ + off);
        __m256 uv = _mm256_loadu_ps(sum + off);
        _mm256_storeu_ps(signed_ + off,
                         _mm256_add_ps(sv, _mm256_mul_ps(ev, gv)));
        _mm256_storeu_ps(sum + off, _mm256_add_ps(uv, gv));
    }
    for (size_t i = main; i < len; i++) {
        float gl = g[i];
        signed_[i] += eps * gl;
        sum[i] += gl;
    }
}

__attribute__((target("avx2"))) static void
fold_signed_block_avx2(const float *signed_, float net, const float *m,
                       float *s, size_t len) {
    size_t main = len - len % 8;
    __m256 nv = _mm256_set1_ps(net);
    for (size_t off = 0; off < main; off += 8) {
        __m256 dv = _mm256_loadu_ps(signed_ + off);
        __m256 mv = _mm256_loadu_ps(m + off);
        __m256 sv = _mm256_loadu_ps(s + off);
        _mm256_storeu_ps(
            s + off,
            _mm256_add_ps(sv, _mm256_sub_ps(dv, _mm256_mul_ps(nv, mv))));
    }
    for (size_t i = main; i < len; i++)
        s[i] += signed_[i] - net * m[i];
}

__attribute__((target("avx2"))) static void
grab_update_avx2(float eps, float inv_n, const float *g, const float *m,
                 float *s, float *fresh, size_t len) {
    size_t main = len - len % 8;
    __m256 ev = _mm256_set1_ps(eps);
    __m256 iv = _mm256_set1_ps(inv_n);
    for (size_t off = 0; off < main; off += 8) {
        __m256 gv = _mm256_loadu_ps(g + off);
        __m256 mv = _mm256_loadu_ps(m + off);
        __m256 sv = _mm256_loadu_ps(s + off);
        __m256 fv = _mm256_loadu_ps(fresh + off);
        _mm256_storeu_ps(
            s + off,
            _mm256_add_ps(sv, _mm256_mul_ps(ev, _mm256_sub_ps(gv, mv))));
        _mm256_storeu_ps(fresh + off,
                         _mm256_add_ps(fv, _mm256_mul_ps(iv, gv)));
    }
    for (size_t i = main; i < len; i++) {
        float gl = g[i];
        s[i] += eps * (gl - m[i]);
        fresh[i] += inv_n * gl;
    }
}

/* ---------------- harness ---------------------------------------------- */

static double now_s(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return (double)ts.tv_sec + 1e-9 * (double)ts.tv_nsec;
}

static float *alloc_vec(size_t len, unsigned seed) {
    float *v = aligned_alloc(64, ((len * 4 + 63) / 64) * 64);
    unsigned x = seed * 2654435761u + 1u;
    for (size_t i = 0; i < len; i++) {
        x = x * 1664525u + 1013904223u;
        v[i] = ((float)(x >> 8) / (float)(1 << 24)) * 2.0f - 1.0f;
    }
    return v;
}

static int bits_eq(float a, float b) {
    uint32_t ua, ub;
    memcpy(&ua, &a, 4);
    memcpy(&ub, &b, 4);
    return ua == ub;
}

static int vec_bits_eq(const float *a, const float *b, size_t len) {
    for (size_t i = 0; i < len; i++)
        if (!bits_eq(a[i], b[i]))
            return 0;
    return 1;
}

static volatile float sink;

typedef void (*bench_fn)(void *ctx);

static double bench_ns(bench_fn f, void *ctx, int iters) {
    for (int i = 0; i < 3; i++)
        f(ctx);
    double best_sum = 0.0;
    double t0 = now_s();
    for (int i = 0; i < iters; i++)
        f(ctx);
    best_sum = now_s() - t0;
    return best_sum / iters * 1e9;
}

struct ctx {
    const float *a, *b, *c;
    float *x, *y;
    size_t len;
};

static void run_dot_scalar(void *p) {
    struct ctx *c = p;
    sink = dot_scalar(c->a, c->b, c->len);
}
static void run_dot_avx2(void *p) {
    struct ctx *c = p;
    sink = dot_avx2(c->a, c->b, c->len);
}
static void run_dc_scalar(void *p) {
    struct ctx *c = p;
    sink = dot_centered_scalar(c->a, c->b, c->c, c->len);
}
static void run_dc_avx2(void *p) {
    struct ctx *c = p;
    sink = dot_centered_avx2(c->a, c->b, c->c, c->len);
}
static void run_dd_scalar(void *p) {
    struct ctx *c = p;
    sink = dot_diff_scalar(c->a, c->b, c->c, c->len);
}
static void run_dd_avx2(void *p) {
    struct ctx *c = p;
    sink = dot_diff_avx2(c->a, c->b, c->c, c->len);
}
static void run_axpy_scalar(void *p) {
    struct ctx *c = p;
    axpy_scalar(0.001f, c->a, c->x, c->len);
}
static void run_axpy_avx2(void *p) {
    struct ctx *c = p;
    axpy_avx2(0.001f, c->a, c->x, c->len);
}
static void run_ad_scalar(void *p) {
    struct ctx *c = p;
    axpy_diff_scalar(1.0f, c->a, c->b, c->x, c->len);
}
static void run_ad_avx2(void *p) {
    struct ctx *c = p;
    axpy_diff_avx2(1.0f, c->a, c->b, c->x, c->len);
}
static void run_ssa_scalar(void *p) {
    struct ctx *c = p;
    sign_sum_accum_scalar(1.0f, c->a, c->x, c->y, c->len);
}
static void run_ssa_avx2(void *p) {
    struct ctx *c = p;
    sign_sum_accum_avx2(1.0f, c->a, c->x, c->y, c->len);
}
static void run_fsb_scalar(void *p) {
    struct ctx *c = p;
    fold_signed_block_scalar(c->a, 2.0f, c->b, c->x, c->len);
}
static void run_fsb_avx2(void *p) {
    struct ctx *c = p;
    fold_signed_block_avx2(c->a, 2.0f, c->b, c->x, c->len);
}
static void run_gu_scalar(void *p) {
    struct ctx *c = p;
    grab_update_scalar(1.0f, 0.001f, c->a, c->b, c->x, c->y, c->len);
}
static void run_gu_avx2(void *p) {
    struct ctx *c = p;
    grab_update_avx2(1.0f, 0.001f, c->a, c->b, c->x, c->y, c->len);
}

static void check_equivalence(size_t len) {
    float *a = alloc_vec(len, 1), *b = alloc_vec(len, 2),
          *c = alloc_vec(len, 3);
    float *x1 = alloc_vec(len, 4), *x2 = alloc_vec(len, 4);
    float *y1 = alloc_vec(len, 5), *y2 = alloc_vec(len, 5);
    memcpy(x2, x1, len * 4);
    memcpy(y2, y1, len * 4);

    if (!bits_eq(dot_scalar(a, b, len), dot_avx2(a, b, len))) {
        fprintf(stderr, "dot mismatch at len=%zu\n", len);
        exit(1);
    }
    if (!bits_eq(dot_centered_scalar(a, b, c, len),
                 dot_centered_avx2(a, b, c, len))) {
        fprintf(stderr, "dot_centered mismatch at len=%zu\n", len);
        exit(1);
    }
    if (!bits_eq(dot_diff_scalar(a, b, c, len),
                 dot_diff_avx2(a, b, c, len))) {
        fprintf(stderr, "dot_diff mismatch at len=%zu\n", len);
        exit(1);
    }
    axpy_scalar(0.37f, a, x1, len);
    axpy_avx2(0.37f, a, x2, len);
    axpy_diff_scalar(-1.0f, a, b, x1, len);
    axpy_diff_avx2(-1.0f, a, b, x2, len);
    sign_sum_accum_scalar(1.0f, a, x1, y1, len);
    sign_sum_accum_avx2(1.0f, a, x2, y2, len);
    fold_signed_block_scalar(a, 3.0f, b, x1, len);
    fold_signed_block_avx2(a, 3.0f, b, x2, len);
    grab_update_scalar(-1.0f, 0.01f, a, b, x1, y1, len);
    grab_update_avx2(-1.0f, 0.01f, a, b, x2, y2, len);
    if (!vec_bits_eq(x1, x2, len) || !vec_bits_eq(y1, y2, len)) {
        fprintf(stderr, "update-kernel mismatch at len=%zu\n", len);
        exit(1);
    }
    free(a); free(b); free(c); free(x1); free(x2); free(y1); free(y2);
}

/* ---------------- JSON snapshot mode (BENCH_*.json schema) -------------- */

/* Serial single-accumulator dot, mirroring tensor::dot_naive: without
 * -ffast-math neither rustc nor gcc may reassociate the float sum, so
 * both stay scalar — the ablation baseline of the perf trajectory. */
static float dot_naive_c(const float *a, const float *b, size_t len) {
    float acc = 0.0f;
    for (size_t i = 0; i < len; i++)
        acc += a[i] * b[i];
    return acc;
}

/* out = a - b, mirroring tensor::sub_into (the two-step baseline). */
static void sub_into_c(const float *a, const float *b, float *out,
                       size_t len) {
    for (size_t i = 0; i < len; i++)
        out[i] = a[i] - b[i];
}

static void run_dot_naive(void *p) {
    struct ctx *c = p;
    sink = dot_naive_c(c->a, c->b, c->len);
}
/* two_step_center_dot: materialize g - m, then dot (a = s, b = g,
 * c = m, x = scratch) — the fused kernels exist to delete this pass. */
static void run_ts_scalar(void *p) {
    struct ctx *c = p;
    sub_into_c(c->b, c->c, c->x, c->len);
    sink = dot_scalar(c->a, c->x, c->len);
}
static void run_ts_avx2(void *p) {
    struct ctx *c = p;
    sub_into_c(c->b, c->c, c->x, c->len);
    sink = dot_avx2(c->a, c->x, c->len);
}

/* Single-policy observe loops: the per-example GraB epoch (decision dot
 * + sign + fused state update, ties to -1 like ordering::grab) and the
 * PairBalance pair chain (dot_diff + axpy_diff).  Permutation
 * bookkeeping (O(n) integer moves) is not mirrored — it is noise next
 * to the O(n*d) float work these rows measure. */
struct epoch_ctx {
    const float *flat;
    float *s, *m, *fresh;
    size_t n, d;
    int avx2;
};

static void run_grab_epoch(void *p) {
    struct epoch_ctx *c = p;
    memset(c->s, 0, c->d * 4);
    memset(c->fresh, 0, c->d * 4);
    float inv_n = 1.0f / (float)c->n;
    for (size_t i = 0; i < c->n; i++) {
        const float *g = c->flat + i * c->d;
        float dot = c->avx2 ? dot_centered_avx2(c->s, g, c->m, c->d)
                            : dot_centered_scalar(c->s, g, c->m, c->d);
        float eps = dot < 0.0f ? 1.0f : -1.0f;
        if (c->avx2)
            grab_update_avx2(eps, inv_n, g, c->m, c->s, c->fresh, c->d);
        else
            grab_update_scalar(eps, inv_n, g, c->m, c->s, c->fresh,
                               c->d);
    }
    sink = c->s[0];
}

static void run_pair_epoch(void *p) {
    struct epoch_ctx *c = p;
    memset(c->s, 0, c->d * 4);
    for (size_t i = 0; i + 1 < c->n; i += 2) {
        const float *a = c->flat + i * c->d;
        const float *b = c->flat + (i + 1) * c->d;
        float dot = c->avx2 ? dot_diff_avx2(c->s, a, b, c->d)
                            : dot_diff_scalar(c->s, a, b, c->d);
        float eps = dot < 0.0f ? 1.0f : -1.0f;
        if (c->avx2)
            axpy_diff_avx2(eps, a, b, c->s, c->d);
        else
            axpy_diff_scalar(eps, a, b, c->s, c->d);
    }
    sink = c->s[0];
}

/* Streaming reservoir window advance (mirror ordering/stream.rs): the
 * static window is exactly the PairBalance chain over the live slots;
 * the churn window adds, per admitted unit, one carry-out axpy (the
 * FIFO-evicted slot's signed contribution leaves the running sum) and
 * one row copy (the admit lands in the freed slot).  Plan derivation
 * (O(rate) integer/RNG bookkeeping) is not mirrored — it is noise next
 * to the O(n*d) float work, like the permutation bookkeeping above. */
struct stream_ctx {
    float *flat;  /* [n × d] live reservoir rows (admits overwrite) */
    float *rows;  /* [rate × d] fresh admit gradients */
    float *s;
    size_t n, d, rate;
    int avx2;
};

static void stream_pair_window(struct stream_ctx *c) {
    memset(c->s, 0, c->d * 4);
    for (size_t i = 0; i + 1 < c->n; i += 2) {
        const float *a = c->flat + i * c->d;
        const float *b = c->flat + (i + 1) * c->d;
        float dot = c->avx2 ? dot_diff_avx2(c->s, a, b, c->d)
                            : dot_diff_scalar(c->s, a, b, c->d);
        float eps = dot < 0.0f ? 1.0f : -1.0f;
        if (c->avx2)
            axpy_diff_avx2(eps, a, b, c->s, c->d);
        else
            axpy_diff_scalar(eps, a, b, c->s, c->d);
    }
    sink = c->s[0];
}

static void run_stream_static(void *p) {
    stream_pair_window((struct stream_ctx *)p);
}

static void run_stream_churn(void *p) {
    struct stream_ctx *c = p;
    stream_pair_window(c);
    for (size_t i = 0; i < c->rate; i++) {
        if (c->avx2)
            axpy_avx2(-1.0f, c->flat + i * c->d, c->s, c->d);
        else
            axpy_scalar(-1.0f, c->flat + i * c->d, c->s, c->d);
        memcpy(c->flat + i * c->d, c->rows + i * c->d, c->d * 4);
    }
    sink = c->s[0];
}

struct jrow {
    char case_name[64];
    long d, n, b, w; /* -1 renders as null */
    const char *kernel;
    double mean_ns;
    int iters;
};

static struct jrow jrows[128];
static int njrows = 0;

static void jrec(const char *case_name, long d, long n, long b, long w,
                 const char *kernel, double mean_ns, int iters) {
    struct jrow *r = &jrows[njrows++];
    snprintf(r->case_name, sizeof r->case_name, "%s", case_name);
    r->d = d;
    r->n = n;
    r->b = b;
    r->w = w;
    r->kernel = kernel;
    r->mean_ns = mean_ns;
    r->iters = iters;
}

static const char *jnum(long v, char *buf, size_t cap) {
    if (v < 0)
        return "null";
    snprintf(buf, cap, "%ld", v);
    return buf;
}

static void git_rev(char *buf, size_t cap) {
    snprintf(buf, cap, "unknown");
    FILE *p = popen("git rev-parse --short HEAD 2>/dev/null", "r");
    if (!p)
        return;
    char tmp[64];
    if (fgets(tmp, sizeof tmp, p)) {
        tmp[strcspn(tmp, "\r\n")] = 0;
        if (tmp[0])
            snprintf(buf, cap, "%s", tmp);
    }
    pclose(p);
}

static void run_json_cases(int quick, const char *path) {
    size_t dims[] = {1024, 7850, 65536};
    for (int tier = 0; tier < 2; tier++) {
        const char *kname = tier ? "simd" : "scalar";
        for (size_t di = 0; di < 3; di++) {
            size_t d = dims[di];
            struct ctx cx;
            cx.a = alloc_vec(d, 11); /* s */
            cx.b = alloc_vec(d, 12); /* g */
            cx.c = alloc_vec(d, 13); /* m */
            cx.x = alloc_vec(d, 14); /* scratch */
            cx.y = alloc_vec(d, 15);
            cx.len = d;
            int iters = quick ? 500 : 20000;
            if (d > 30000)
                iters /= 10;
            char name[64];

            /* dot_naive is kernel-independent; recorded under every
             * tier label as a per-tier noise floor (like grab bench). */
            snprintf(name, sizeof name, "dot_naive/d%zu", d);
            jrec(name, (long)d, -1, -1, -1, kname,
                 bench_ns(run_dot_naive, &cx, iters), iters);
            snprintf(name, sizeof name, "dot_unrolled/d%zu", d);
            jrec(name, (long)d, -1, -1, -1, kname,
                 bench_ns(tier ? run_dot_avx2 : run_dot_scalar, &cx,
                          iters),
                 iters);
            snprintf(name, sizeof name, "two_step_center_dot/d%zu", d);
            jrec(name, (long)d, -1, -1, -1, kname,
                 bench_ns(tier ? run_ts_avx2 : run_ts_scalar, &cx,
                          iters),
                 iters);
            snprintf(name, sizeof name, "fused_dot_centered/d%zu", d);
            jrec(name, (long)d, -1, -1, -1, kname,
                 bench_ns(tier ? run_dc_avx2 : run_dc_scalar, &cx,
                          iters),
                 iters);

            size_t n = 256;
            struct epoch_ctx ec;
            ec.flat = alloc_vec(n * d, 21);
            ec.s = cx.x;
            ec.m = (float *)cx.c;
            ec.fresh = cx.y;
            ec.n = n;
            ec.d = d;
            ec.avx2 = tier;
            int eiters = quick ? 2 : (d > 30000 ? 20 : 100);
            snprintf(name, sizeof name, "grab_observe_epoch/n%zu/d%zu",
                     n, d);
            jrec(name, (long)d, (long)n, -1, -1, kname,
                 bench_ns(run_grab_epoch, &ec, eiters), eiters);
            free((void *)ec.flat);

            free((void *)cx.a);
            free((void *)cx.b);
            free((void *)cx.c);
            free(cx.x);
            free(cx.y);
        }

        size_t d = 4096, n = 512;
        struct epoch_ctx ec;
        ec.flat = alloc_vec(n * d, 31);
        ec.s = alloc_vec(d, 32);
        ec.m = NULL;
        ec.fresh = NULL;
        ec.n = n;
        ec.d = d;
        ec.avx2 = tier;
        int piters = quick ? 3 : 200;
        char name[64];
        snprintf(name, sizeof name, "pair_observe/block64/n%zu/d%zu", n,
                 d);
        jrec(name, (long)d, (long)n, 64, -1, kname,
             bench_ns(run_pair_epoch, &ec, piters), piters);
        free((void *)ec.flat);
        free(ec.s);

        /* Streaming reservoir: window advance cost vs reservoir size
         * (mirrors the grab-bench stream_window cases at d = 256,
         * B = 64; rate = n/16 count-neutral admits per window). */
        size_t sizes[] = {256, 1024, 4096};
        for (size_t si = 0; si < 3; si++) {
            size_t sn = sizes[si], sd = 256;
            struct stream_ctx sc;
            sc.n = sn;
            sc.d = sd;
            sc.rate = sn / 16;
            sc.avx2 = tier;
            sc.flat = alloc_vec(sn * sd, 41);
            sc.rows = alloc_vec(sc.rate * sd, 42);
            sc.s = alloc_vec(sd, 43);
            int siters = quick ? 3 : (sn >= 4096 ? 60 : 200);
            snprintf(name, sizeof name,
                     "stream_window/static/n%zu/d%zu", sn, sd);
            jrec(name, (long)sd, (long)sn, 64, -1, kname,
                 bench_ns(run_stream_static, &sc, siters), siters);
            snprintf(name, sizeof name,
                     "stream_window/churn%zu/n%zu/d%zu", sc.rate, sn,
                     sd);
            jrec(name, (long)sd, (long)sn, 64, -1, kname,
                 bench_ns(run_stream_churn, &sc, siters), siters);
            free(sc.flat);
            free(sc.rows);
            free(sc.s);
        }
    }

    char rev[64];
    git_rev(rev, sizeof rev);
    FILE *f = fopen(path, "w");
    if (!f) {
        fprintf(stderr, "cannot write %s\n", path);
        exit(1);
    }
    fprintf(f, "{\n  \"schema_version\": 1,\n");
    fprintf(f, "  \"runner\": \"c-mirror\",\n");
    fprintf(f, "  \"git_rev\": \"%s\",\n", rev);
    fprintf(f, "  \"results\": [\n");
    for (int i = 0; i < njrows; i++) {
        struct jrow *r = &jrows[i];
        char bd[24], bn[24], bb[24], bw[24];
        fprintf(f,
                "    {\"case\": \"%s\", \"d\": %s, \"n\": %s, "
                "\"B\": %s, \"W\": %s, \"kernel\": \"%s\", "
                "\"mean_ns\": %.1f, \"iters\": %d}%s\n",
                r->case_name, jnum(r->d, bd, sizeof bd),
                jnum(r->n, bn, sizeof bn), jnum(r->b, bb, sizeof bb),
                jnum(r->w, bw, sizeof bw), r->kernel, r->mean_ns,
                r->iters, i + 1 < njrows ? "," : "");
    }
    fprintf(f, "  ]\n}\n");
    fclose(f);
    fprintf(stderr, "wrote %d rows to %s (rev %s)\n", njrows, path, rev);
}

int main(int argc, char **argv) {
    int quick = 0;
    const char *json_path = NULL;
    for (int i = 1; i < argc; i++) {
        if (strcmp(argv[i], "--quick") == 0) {
            quick = 1;
        } else if (strcmp(argv[i], "--json") == 0 && i + 1 < argc) {
            json_path = argv[++i];
        } else {
            fprintf(stderr,
                    "usage: bench_mirror [--quick] [--json FILE]\n");
            return 2;
        }
    }

    size_t checks[] = {1, 7, 8, 9, 15, 16, 63, 1024, 7850, 65537};
    for (size_t i = 0; i < sizeof(checks) / sizeof(checks[0]); i++)
        check_equivalence(checks[i]);
    fprintf(stderr, "bit-equivalence: OK\n");

    if (json_path) {
        run_json_cases(quick, json_path);
        return 0;
    }

    size_t dims[] = {1024, 7850, 65536};
    struct {
        const char *name;
        bench_fn scalar, avx2;
    } cases[] = {
        {"dot", run_dot_scalar, run_dot_avx2},
        {"dot_centered", run_dc_scalar, run_dc_avx2},
        {"dot_diff", run_dd_scalar, run_dd_avx2},
        {"axpy", run_axpy_scalar, run_axpy_avx2},
        {"axpy_diff", run_ad_scalar, run_ad_avx2},
        {"sign_sum_accum", run_ssa_scalar, run_ssa_avx2},
        {"fold_signed_block", run_fsb_scalar, run_fsb_avx2},
        {"grab_update", run_gu_scalar, run_gu_avx2},
    };

    printf("%-20s %8s %14s %14s %8s\n", "kernel", "d", "scalar_ns",
           "avx2_ns", "speedup");
    for (size_t di = 0; di < 3; di++) {
        size_t d = dims[di];
        struct ctx cx;
        cx.a = alloc_vec(d, 11);
        cx.b = alloc_vec(d, 12);
        cx.c = alloc_vec(d, 13);
        cx.x = alloc_vec(d, 14);
        cx.y = alloc_vec(d, 15);
        cx.len = d;
        int iters = quick ? 2000 : 20000;
        if (d > 30000)
            iters /= 4;
        for (size_t ci = 0; ci < sizeof(cases) / sizeof(cases[0]); ci++) {
            double s = bench_ns(cases[ci].scalar, &cx, iters);
            double v = bench_ns(cases[ci].avx2, &cx, iters);
            printf("%-20s %8zu %14.1f %14.1f %7.2fx\n", cases[ci].name, d,
                   s, v, s / v);
        }
        free((void *)cx.a); free((void *)cx.b); free((void *)cx.c);
        free(cx.x); free(cx.y);
    }
    return 0;
}
